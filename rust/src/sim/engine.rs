//! The sim driver: the discrete-event loop that feeds observations to a
//! [`Policy`] and applies its actions to [`SimState`], keeping every
//! accounting invariant (energy, cost, deadlines, peaks) in one place.
//!
//! [`Driver`] is the reusable stepping core: `sim::run` drives it to
//! completion as fast as possible, while the serving runtime
//! (`crate::serve`) paces the *same* core against the wall clock and
//! mirrors each applied [`Effect`] onto real worker threads — which is
//! what makes served behavior equal simulated behavior by construction.

use super::event::{Event, EventQueue};
use super::metrics::{feasible_miss_budget, IdealBaseline, Metrics, RunResult};
use super::pool::Pool;
use super::worker::{Worker, WorkerId, WorkerState};
use crate::config::{PlatformConfig, SimConfig, WorkerKind};
use crate::policy::{Action, Effect, Observation, Policy, PolicyView, Request, Target, WorkerObs};
use crate::scenario::{Fault, FaultPlan, ScenarioConfig};
use crate::trace::{AppTrace, Arrival, ArrivalSource};
use std::collections::{HashMap, HashSet};

/// Latency subsampling factor (1/N of completions recorded).
const LATENCY_SAMPLE: u64 = 61;

/// Live scenario state: the current spot-price multiplier per kind and its
/// running time integral, which is what spot-billed workers are charged
/// against (cost = on-demand rate × ∫ price(t) dt over the lifetime).
struct ScenarioState {
    cfg: ScenarioConfig,
    /// Current price multiplier per kind (by [`WorkerKind::index`]).
    price: [f64; 2],
    /// ∫ price dt accumulated up to `last_t`, per kind.
    integral: [f64; 2],
    last_t: [f64; 2],
}

/// Simulation state owned by the driver. All allocation, dispatch, and
/// retirement flows through this API so energy/cost accounting stays
/// consistent; policies only ever see it through [`PolicyView`].
pub struct SimState {
    pub cfg: SimConfig,
    pub pool: Pool,
    pub metrics: Metrics,
    now: f64,
    events: EventQueue,
    /// Service-time sums dispatched this interval, per kind (Alg 1's
    /// 𝓕 and 𝓒 inputs). Reset by `take_interval_work`.
    interval_work_cpu: f64,
    interval_work_fpga: f64,
    completions_seen: u64,
    /// End of the arrival window (trace duration).
    trace_end: f64,
    /// Attached scenario (spot prices + fault plan), if any. `None` keeps
    /// every fault-path branch dead and the run bit-identical to the
    /// pre-scenario engine.
    scenario: Option<ScenarioState>,
    /// Never-reused dispatch sequence counter, stamped onto each in-flight
    /// entry and its completion event (hedge-pair identity).
    next_seq: u64,
    /// Open hedge pairs: each member's seq maps to `(partner_seq, is_dup)`.
    /// Empty unless a policy issued [`Action::Hedge`], so the fault-free
    /// path pays one empty-map lookup per completion and nothing else.
    hedge_partner: HashMap<u64, (u64, bool)>,
    /// Losing halves of settled hedges: their completion (or kill-drain)
    /// must free the worker without booking the request again.
    hedge_cancelled: HashSet<u64>,
}

impl SimState {
    pub fn new(cfg: SimConfig) -> Self {
        Self {
            cfg,
            pool: Pool::new(),
            metrics: Metrics::default(),
            now: 0.0,
            events: EventQueue::new(),
            interval_work_cpu: 0.0,
            interval_work_fpga: 0.0,
            completions_seen: 0,
            trace_end: f64::INFINITY,
            scenario: None,
            next_seq: 0,
            hedge_partner: HashMap::new(),
            hedge_cancelled: HashSet::new(),
        }
    }

    /// Whether `kind` is spot-billed under the attached scenario.
    pub fn kind_is_spot(&self, kind: WorkerKind) -> bool {
        self.scenario
            .as_ref()
            .map_or(false, |s| s.cfg.kinds[kind.index()].spot)
    }

    /// Current spot-price multiplier of `kind` (1.0 outside a scenario).
    pub fn kind_spot_price(&self, kind: WorkerKind) -> f64 {
        self.scenario
            .as_ref()
            .map_or(1.0, |s| s.price[kind.index()])
    }

    /// ∫ price(t) dt from t=0 to now for `kind` — the billing clock of
    /// spot workers (a worker pays rate × (C(dealloc) − C(alloc))).
    fn price_integral_now(&self, kind: WorkerKind) -> f64 {
        match &self.scenario {
            Some(s) => {
                let k = kind.index();
                s.integral[k] + s.price[k] * (self.now - s.last_t[k])
            }
            None => 0.0,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Whether the arrival window is still open (schedulers pinning fleets
    /// release them once the trace ends so the pool can drain).
    pub fn trace_live(&self) -> bool {
        self.now < self.trace_end
    }

    /// Service time of a `size`-CPU-seconds request on `kind`.
    pub fn service_time(&self, kind: WorkerKind, size: f64) -> f64 {
        self.cfg.platform.params(kind).service_time(size)
    }

    /// Number of allocated (spinning-up or active) workers of `kind`.
    pub fn allocated(&self, kind: WorkerKind) -> u32 {
        self.pool.allocated(kind)
    }

    /// Spin up a new worker. Returns `None` if the configured cap is
    /// reached. Alloc energy (busy power over the spin-up window) is
    /// accounted immediately.
    pub fn alloc(&mut self, kind: WorkerKind) -> Option<WorkerId> {
        self.alloc_inner(kind, false)
    }

    fn alloc_inner(&mut self, kind: WorkerKind, warm: bool) -> Option<WorkerId> {
        let cap = match kind {
            WorkerKind::Cpu => self.cfg.max_cpus,
            WorkerKind::Fpga => self.cfg.max_fpgas,
        };
        let current = self.pool.allocated(kind);
        if let Some(cap) = cap {
            if current >= cap {
                return None;
            }
        }
        let params = *self.cfg.platform.params(kind);
        let now = self.now;
        // Spot workers bill against the price-path integral; snapshot the
        // billing clock at allocation (0.0 outside a scenario).
        let basis = if self.kind_is_spot(kind) {
            self.price_integral_now(kind)
        } else {
            0.0
        };
        let id = self.pool.insert(|id| {
            let mut w = Worker::new(id, kind, now, params.spin_up, current);
            w.cost_basis = basis;
            w
        });
        let uid = self.pool.get(id).expect("just inserted").uid;
        // Warm allocs go Active immediately (the caller flips the state in
        // this same transaction group), so their SpinUpDone would be a
        // guaranteed no-op — skip it instead of bloating the event heap by
        // one dead entry per worker of a large pre-warmed fleet.
        if !warm {
            self.events
                .push(now + params.spin_up, Event::SpinUpDone { worker: id, uid });
        }
        self.metrics.energy_mut(kind).alloc += params.spin_up_energy();
        // Peak tracks *allocated* workers (spinning-up + active), matching
        // the cap semantics; spinning-down workers are draining capacity.
        let allocated_now = current + 1;
        match kind {
            WorkerKind::Cpu => {
                self.metrics.cpu_spinups += 1;
                self.metrics.peak_cpus = self.metrics.peak_cpus.max(allocated_now);
            }
            WorkerKind::Fpga => {
                self.metrics.fpga_spinups += 1;
                self.metrics.peak_fpgas = self.metrics.peak_fpgas.max(allocated_now);
            }
        }
        Some(id)
    }

    /// Allocate a worker that is already warm (statically provisioned
    /// before the workload window — FPGA-static's fleet). The one-time
    /// spin-up energy is still charged, but the worker is Active now and
    /// no `SpinUpDone` is scheduled (the `handle_event` guard stays as a
    /// defensive no-op for any stray event).
    pub fn alloc_warm(&mut self, kind: WorkerKind) -> Option<WorkerId> {
        let id = self.alloc_inner(kind, true)?;
        let now = self.now;
        self.pool.with_mut(id, |w| {
            w.state = WorkerState::Active;
            w.ready_at = now;
            w.busy_until = now;
            w.idle_since = now;
        });
        self.schedule_idle_timeout(id);
        Some(id)
    }

    /// Would `worker` finish a `size` request by `deadline` if dispatched
    /// now? Uses the canonical feasibility comparison
    /// (`busy_until.max(now) <= deadline - svc`) so the answer always
    /// agrees with the indexed dispatch queries.
    pub fn can_finish(&self, worker: WorkerId, size: f64, deadline: f64) -> bool {
        let w = self.pool.get(worker).expect("can_finish: unknown worker");
        let svc = self.service_time(w.kind, size);
        w.accepting() && w.busy_until.max(self.now) <= deadline - svc
    }

    /// Dispatch a request to a specific worker; returns the completion
    /// time and the dispatch's never-reused sequence number (hedge-pair
    /// identity). Busy energy is attributed at dispatch; a scenario kill
    /// refunds the unexecuted remainder, so the invariant "charged busy
    /// energy == executed service time × busy power" holds either way.
    ///
    /// Retries (`req.attempt > 0`) are re-dispatches of work already
    /// counted at first dispatch: they charge energy and interval work
    /// (real compute) but not the arrival-side counters (`requests`,
    /// `on_cpu`/`on_fpga`, `total_work`), so arrival conservation
    /// (`requests == completions + abandoned`) holds under faults.
    pub fn dispatch(&mut self, req: Request, worker: WorkerId) -> (f64, u64) {
        let now = self.now;
        let seq = self.next_seq;
        self.next_seq += 1;
        // One slab transaction on the per-request hot path: kind read,
        // service-time lookup, and assignment in a single with_mut.
        let (kind, svc, finish, uid) = self.pool.with_mut(worker, |w| {
            debug_assert!(w.accepting(), "dispatch to spinning-down worker");
            let svc = self.cfg.platform.params(w.kind).service_time(req.size);
            let finish = w.assign(now, svc);
            w.inflight.push_back((req, seq));
            (w.kind, svc, finish, w.uid)
        });
        self.events.push(
            finish,
            Event::Completion {
                worker,
                uid,
                seq,
                arrival: req.arrival,
                deadline: req.deadline,
            },
        );
        let params = self.cfg.platform.params(kind);
        self.metrics.energy_mut(kind).busy += svc * params.busy_power;
        if req.attempt == 0 {
            self.metrics.requests += 1;
            self.metrics.total_work += req.size;
            match kind {
                WorkerKind::Cpu => self.metrics.on_cpu += 1,
                WorkerKind::Fpga => self.metrics.on_fpga += 1,
            }
        }
        match kind {
            WorkerKind::Cpu => self.interval_work_cpu += svc,
            WorkerKind::Fpga => self.interval_work_fpga += svc,
        }
        (finish, seq)
    }

    /// Scenario kill: remove a live accepting worker *now*, without a
    /// spin-down window, and return its drained in-flight requests (FIFO).
    ///
    /// Accounting: idle energy accrued to the kill instant is charged (as
    /// retirement would); busy energy charged at dispatch for the
    /// *unexecuted* remainder is refunded; executed-but-never-completed
    /// service time is tallied as `work_lost`. Cost is the price-path
    /// integral for spot-billed kinds, plain lifetime × rate otherwise.
    /// No spin-down energy is charged — preemption reclaims the worker
    /// instantly.
    pub fn kill(&mut self, worker: WorkerId) -> Vec<(Request, u64)> {
        let now = self.now;
        let mut w = self.pool.remove(worker);
        debug_assert!(w.accepting(), "scenario kill of spinning-down worker");
        let params = self.cfg.platform.params(w.kind);
        // Queued-but-unexecuted service time at the kill instant.
        let remaining = (w.busy_until - now.max(w.ready_at)).max(0.0);
        let executed = (w.busy_seconds - remaining).max(0.0);
        let idle_secs = (w.active_seconds(now) - executed).max(0.0);
        self.metrics.energy_mut(w.kind).idle += idle_secs * params.idle_power;
        self.metrics.energy_mut(w.kind).busy -= remaining * params.busy_power;
        self.metrics.work_lost += (executed - w.completed_seconds).max(0.0);
        let cost = if self.kind_is_spot(w.kind) {
            params.cost_per_sec() * (self.price_integral_now(w.kind) - w.cost_basis)
        } else {
            (now - w.alloc_time) * params.cost_per_sec()
        };
        match w.kind {
            WorkerKind::Cpu => self.metrics.cpu_cost += cost,
            WorkerKind::Fpga => self.metrics.fpga_cost += cost,
        }
        std::mem::take(&mut w.inflight).into()
    }

    /// Book one completion on `worker`: pop its oldest in-flight request,
    /// credit the executed service time, and return whether the worker
    /// went idle plus the popped request. When `count` is false (the
    /// losing half of a settled hedge pair), the worker-side bookkeeping
    /// still happens — the duplicate really executed — but
    /// `metrics.completions` is untouched: exactly one completion per
    /// request, which is what keeps the conservation law exact.
    fn complete_request(&mut self, worker: WorkerId, count: bool) -> (bool, Request, u64) {
        let now = self.now;
        let (went_idle, req, seq) = self.pool.with_mut(worker, |w| {
            let (req, seq) = w.inflight.pop_front().expect("completion on empty inflight queue");
            let svc = self.cfg.platform.params(w.kind).service_time(req.size);
            w.completed_seconds += svc;
            (w.complete_one(now), req, seq)
        });
        if count {
            self.metrics.completions += 1;
        }
        (went_idle, req, seq)
    }

    /// Begin spin-down of an idle or never-used worker. Accounts idle
    /// energy accrued over its active window and the spin-down energy.
    pub fn retire(&mut self, worker: WorkerId) {
        let now = self.now;
        let (kind, idle_secs, uid) = self.pool.with_mut(worker, |w| {
            debug_assert!(
                w.state == WorkerState::Active && w.queued == 0,
                "retire requires an idle worker"
            );
            let idle_secs = w.idle_seconds(now);
            w.state = WorkerState::SpinningDown;
            (w.kind, idle_secs, w.uid)
        });
        let params = self.cfg.platform.params(kind);
        self.metrics.energy_mut(kind).idle += idle_secs * params.idle_power;
        self.metrics.energy_mut(kind).dealloc += params.spin_down_energy();
        self.events
            .push(now + params.spin_down, Event::SpinDownDone { worker, uid });
    }

    /// Retire up to `n` idle workers of `kind`, longest-idle first —
    /// the head of the pool's idle index (no sort-per-decision).
    pub fn retire_idle(&mut self, kind: WorkerKind, n: u32) -> Vec<WorkerId> {
        let ids: Vec<WorkerId> = self.pool.idle_ordered(kind).take(n as usize).collect();
        for &id in &ids {
            self.retire(id);
        }
        ids
    }

    /// Drain and reset the per-interval dispatched-work counters
    /// (CPU service-seconds, FPGA service-seconds).
    pub fn take_interval_work(&mut self) -> (f64, f64) {
        let out = (self.interval_work_cpu, self.interval_work_fpga);
        self.interval_work_cpu = 0.0;
        self.interval_work_fpga = 0.0;
        out
    }

    fn schedule_idle_timeout(&mut self, worker: WorkerId) {
        let w = self.pool.get(worker).expect("timeout: unknown worker");
        let timeout = match w.kind {
            WorkerKind::Cpu => self.cfg.cpu_idle_timeout,
            WorkerKind::Fpga => self.cfg.fpga_idle_timeout,
        };
        self.events.push(
            self.now + timeout,
            Event::IdleTimeout {
                worker,
                uid: w.uid,
                generation: w.generation,
            },
        );
    }

    fn worker_obs(w: &Worker) -> WorkerObs {
        WorkerObs {
            id: w.id,
            kind: w.kind,
            state: w.state,
            ready_at: w.ready_at,
            busy_until: w.busy_until,
            queued: w.queued,
            idle_since: w.idle_since,
        }
    }
}

impl PolicyView for SimState {
    fn now(&self) -> f64 {
        self.now
    }

    fn trace_live(&self) -> bool {
        SimState::trace_live(self)
    }

    fn service_time(&self, kind: WorkerKind, size: f64) -> f64 {
        SimState::service_time(self, kind, size)
    }

    fn allocated(&self, kind: WorkerKind) -> u32 {
        self.pool.allocated(kind)
    }

    fn live_ids(&self, kind: WorkerKind) -> Vec<WorkerId> {
        self.pool.live_ids(kind)
    }

    fn worker(&self, id: WorkerId) -> Option<WorkerObs> {
        self.pool.get(id).map(SimState::worker_obs)
    }

    fn for_each_worker(&self, kind: WorkerKind, f: &mut dyn FnMut(&WorkerObs)) {
        for w in self.pool.iter_kind(kind) {
            f(&SimState::worker_obs(w));
        }
    }

    // Indexed overrides of the dispatch hot-path queries: identical
    // results to the trait's reference scans (including lowest-id ties —
    // pinned by `rust/tests/dispatch_parity.rs`), answered off the pool's
    // ordered indexes instead of a fleet-sized scan.

    fn for_each_live_id_after(
        &self,
        kind: WorkerKind,
        after: Option<WorkerId>,
        f: &mut dyn FnMut(WorkerId) -> bool,
    ) {
        match after {
            Some(a) => {
                for id in self.pool.live_ids_after(kind, a) {
                    if !f(id) {
                        return;
                    }
                }
            }
            None => {
                for id in self.pool.live_ids_iter(kind) {
                    if !f(id) {
                        return;
                    }
                }
            }
        }
    }

    fn busiest_busy_feasible(&self, kind: WorkerKind, bound: f64) -> Option<(f64, WorkerId)> {
        self.pool.busiest_busy(kind, bound)
    }

    fn most_recently_idle(&self, kind: WorkerKind) -> Option<(f64, WorkerId)> {
        self.pool.most_recently_idle(kind)
    }

    fn most_loaded_spinup_feasible(&self, kind: WorkerKind, bound: f64) -> Option<(f64, WorkerId)> {
        self.pool.most_loaded_spinup(kind, bound)
    }

    fn busiest_packed_feasible(&self, kind: WorkerKind, bound: f64) -> Option<(f64, WorkerId)> {
        self.pool.busiest_packed(kind, bound)
    }

    fn earliest_ready(&self, kind: WorkerKind) -> Option<(f64, WorkerId)> {
        self.pool.earliest_ready(kind)
    }

    fn inflight_requests(&self) -> u64 {
        self.pool.inflight_total()
    }

    fn spot_price(&self, kind: WorkerKind) -> f64 {
        self.kind_spot_price(kind)
    }

    fn is_spot(&self, kind: WorkerKind) -> bool {
        self.kind_is_spot(kind)
    }
}

/// The stepping core shared by both drivers: merges a time-ordered
/// [`ArrivalSource`] (pulled lazily, one look-ahead) with the event heap
/// and interval ticks, observes the policy at each occurrence, and
/// applies the returned actions to [`SimState`]. Every applied side
/// effect is reported to the caller's sink.
///
/// Memory is bounded by the worker pool and the in-flight event heap —
/// never by trace length, which is what lets a single driver replay
/// million-request (or unbounded) streams.
pub struct Driver<'a> {
    sim: SimState,
    policy: &'a mut dyn Policy,
    source: Box<dyn ArrivalSource + 'a>,
    /// One-arrival look-ahead (`frontier` needs the next arrival time
    /// without consuming it).
    pending: Option<Arrival>,
    /// Time of the last pulled arrival, to fail loudly on an unsorted or
    /// NaN-bearing source before it can corrupt the run.
    last_arrival: f64,
    interval: f64,
    next_tick: f64,
    tick_index: usize,
    deadline_factor: f64,
    actions: Vec<Action>,
    /// The source's exact arrival count, captured from `len_hint()`
    /// before the first pull (None = unknown). Validated at exhaustion.
    expected_arrivals: Option<u64>,
    /// Arrivals pulled from the source so far.
    pulled_arrivals: u64,
    /// Early-abort threshold: stop the run once `deadline_misses`
    /// exceeds this (see [`Driver::abort_on_excess_misses`]).
    miss_budget: Option<u64>,
    /// Whether the run was stopped by the miss budget.
    aborted: bool,
}

impl<'a> Driver<'a> {
    pub fn new(trace: &'a AppTrace, cfg: SimConfig, policy: &'a mut dyn Policy) -> Self {
        Self::from_source(Box::new(trace.source()), cfg, policy)
    }

    /// Drive a streaming source directly (constant memory in the trace
    /// length). The source's `duration()` is the arrival-window end that
    /// gates ticks and fleet pinning.
    pub fn from_source(
        mut source: Box<dyn ArrivalSource + 'a>,
        cfg: SimConfig,
        policy: &'a mut dyn Policy,
    ) -> Self {
        let mut sim = SimState::new(cfg);
        sim.trace_end = source.duration();
        assert!(
            sim.trace_end >= 0.0 && !sim.trace_end.is_nan(),
            "source '{}' has an invalid duration",
            source.name()
        );
        let deadline_factor = sim.cfg.deadline_factor;
        let interval = policy.interval();
        let next_tick = if interval.is_finite() { interval } else { f64::INFINITY };
        // Capture the exact-count hint before the first pull consumes an
        // arrival the hint would no longer cover.
        let expected_arrivals = source.len_hint();
        let pending = source.next_arrival();
        let mut driver = Self {
            sim,
            policy,
            source,
            pending: None,
            last_arrival: f64::NEG_INFINITY,
            interval,
            next_tick,
            tick_index: 1,
            deadline_factor,
            actions: Vec::new(),
            expected_arrivals,
            pulled_arrivals: 0,
            miss_budget: None,
            aborted: false,
        };
        driver.admit(pending);
        driver
    }

    /// Validate and stage the next pulled arrival.
    fn admit(&mut self, a: Option<Arrival>) {
        if let Some(a) = a {
            assert!(
                a.time.is_finite() && a.time >= self.last_arrival,
                "source '{}' is not time-ordered (or yields NaN) at t={}",
                self.source.name(),
                a.time
            );
            assert!(
                a.size > 0.0 && a.size.is_finite(),
                "source '{}' yields invalid size {} at t={}",
                self.source.name(),
                a.size,
                a.time
            );
            self.last_arrival = a.time;
            self.pulled_arrivals += 1;
        } else if let Some(n) = self.expected_arrivals {
            // len_hint is a contract, not an estimate: a miscount would
            // invalidate any budget derived from it (early abort), so
            // fail loudly at the first exhaustion.
            assert!(
                self.pulled_arrivals == n,
                "source '{}' declared len_hint {} but yielded {} arrivals",
                self.source.name(),
                n,
                self.pulled_arrivals
            );
        }
        self.pending = a;
    }

    /// Arm the early-abort stop condition: the run halts (and
    /// [`Driver::aborted`] reads true) the moment `deadline_misses`
    /// exceeds the largest count still compatible with
    /// `miss_fraction() <= miss_tolerance` at the end of a full pass.
    /// Misses are monotone over a run, so an aborted run is *provably*
    /// infeasible — and a feasible run never trips the budget, so arming
    /// it cannot change a feasible run's result. Requires the source's
    /// exact arrival count ([`ArrivalSource::len_hint`]); returns whether
    /// the condition armed (false = unknown length, run is unbounded).
    pub fn abort_on_excess_misses(&mut self, miss_tolerance: f64) -> bool {
        match self.expected_arrivals {
            Some(total) => {
                self.miss_budget = Some(feasible_miss_budget(total, miss_tolerance));
                true
            }
            None => false,
        }
    }

    /// Whether the run was stopped early by the miss budget.
    pub fn aborted(&self) -> bool {
        self.aborted
    }

    /// Attach a scenario with a pre-built fault plan: push every planned
    /// fault into the event heap and arm the spot-price state. Must be
    /// called before stepping. An empty plan with no spot kinds (the
    /// fault-free pack) leaves the run bit-identical to no attach at all.
    pub fn attach_plan(&mut self, cfg: &ScenarioConfig, plan: &FaultPlan) {
        // Invalid packs are configuration errors, not adversity: fail loud
        // before any fault event enters the heap. CLI paths validate with
        // a friendly error earlier; this is the backstop for embedders.
        if let Err(e) = cfg.validate() {
            panic!("invalid scenario config: {e}");
        }
        let mut price = [1.0f64; 2];
        for (k, ks) in cfg.kinds.iter().enumerate() {
            if ks.spot {
                price[k] = ks.price.init.max(ks.price.floor);
            }
        }
        for pf in &plan.faults {
            let event = match pf.fault {
                Fault::PriceTick { kind, price } => Event::PriceTick { kind, price },
                Fault::Preemption { kind, victim_draw } => {
                    Event::Preempted { kind, victim_draw }
                }
                Fault::Failure { kind, victim_draw } => {
                    Event::WorkerFailed { kind, victim_draw }
                }
            };
            self.sim.events.push(pf.time, event);
        }
        self.sim.scenario = Some(ScenarioState {
            cfg: cfg.clone(),
            price,
            integral: [0.0; 2],
            last_t: [0.0; 2],
        });
    }

    /// Attach a scenario, deriving its fault plan from `(seed_base, seed)`
    /// over this run's arrival window — the plan is a pure function of
    /// those seeds and the scenario config, independent of the policy and
    /// of how runs are batched across threads. Returns the plan so callers
    /// can report its composition.
    pub fn attach_scenario(&mut self, cfg: &ScenarioConfig, seed_base: u64, seed: u64) -> FaultPlan {
        let plan = FaultPlan::build(cfg, seed_base, seed, self.sim.trace_end);
        self.attach_plan(cfg, &plan);
        plan
    }

    /// Arrivals pulled from the source so far — processed arrivals plus
    /// the one-arrival look-ahead while the stream is unexhausted. The
    /// lockstep runner's frontier: drivers of one [`tee`] fan-out stay
    /// within one pulled arrival of each other so the shared buffer
    /// holds O(1) arrivals.
    ///
    /// [`tee`]: crate::trace::tee
    pub fn arrivals_pulled(&self) -> u64 {
        self.pulled_arrivals
    }

    /// Whether the source has been fully consumed (no look-ahead staged).
    pub fn source_exhausted(&self) -> bool {
        self.pending.is_none()
    }

    pub fn now(&self) -> f64 {
        self.sim.now
    }

    pub fn metrics(&self) -> &Metrics {
        &self.sim.metrics
    }

    /// Observe `Start` at t = 0 (pre-provisioning). Call once before
    /// stepping.
    pub fn start(&mut self, sink: &mut dyn FnMut(&Effect)) {
        self.observe(Observation::Start, sink);
    }

    /// Times of the next arrival, event, and tick (infinity = exhausted).
    /// The single source of truth for both `next_time` and `step`, so the
    /// real-time driver's pacing target always matches what `step`
    /// processes.
    fn frontier(&self) -> (f64, f64, f64) {
        let ta = self.pending.map(|a| a.time).unwrap_or(f64::INFINITY);
        let te = self.sim.events.peek_time().unwrap_or(f64::INFINITY);
        // Ticks only while the trace is live; cleanup needs no allocator.
        let tt = if self.next_tick <= self.sim.trace_end {
            self.next_tick
        } else {
            f64::INFINITY
        };
        (ta, te, tt)
    }

    /// Simulated time of the next occurrence, or `None` when the run is
    /// complete (trace consumed and pool drained).
    pub fn next_time(&self) -> Option<f64> {
        let (ta, te, tt) = self.frontier();
        let t = ta.min(te).min(tt);
        t.is_finite().then_some(t)
    }

    /// Process the next occurrence (tick, event, or arrival). Returns
    /// `false` when the run is complete — or, with
    /// [`Driver::abort_on_excess_misses`] armed, the moment the miss
    /// budget is exceeded (the run is then provably infeasible and the
    /// rest of the trace carries no information the caller needs).
    pub fn step(&mut self, sink: &mut dyn FnMut(&Effect)) -> bool {
        if let Some(budget) = self.miss_budget {
            if self.sim.metrics.deadline_misses > budget {
                self.aborted = true;
                return false;
            }
        }
        let (ta, te, tt) = self.frontier();
        let t = ta.min(te).min(tt);
        if !t.is_finite() {
            return false;
        }
        self.sim.now = t;

        if tt <= ta && tt <= te {
            self.next_tick += self.interval;
            let index = self.tick_index;
            self.tick_index += 1;
            let (cpu_work, fpga_work) = self.sim.take_interval_work();
            self.observe(
                Observation::Tick {
                    index,
                    cpu_work,
                    fpga_work,
                },
                sink,
            );
            return true;
        }
        if te <= ta {
            let (_, event) = self.sim.events.pop().unwrap();
            self.handle_event(event, sink);
            return true;
        }
        let a = self.pending.expect("frontier said an arrival is due");
        let next = self.source.next_arrival();
        self.admit(next);
        let req = Request {
            arrival: a.time,
            size: a.size,
            deadline: a.time + self.deadline_factor * a.size,
            attempt: 0,
        };
        self.observe(Observation::Arrival { req }, sink);
        true
    }

    /// Batched admission: process every occurrence due at or before
    /// `horizon` (sim seconds) in one burst, with no pacing between them.
    /// Exactly a loop over [`Driver::step`] — same occurrence order, same
    /// observations, same effects, bit for bit — which is what lets the
    /// real-time router amortize one wall-clock wakeup over a whole pacing
    /// quantum without perturbing policy behavior (pinned by
    /// `rust/tests/serve_line_rate.rs`). Returns the number of occurrences
    /// processed; stops early if the run completes or the miss budget
    /// aborts it.
    pub fn step_until(&mut self, horizon: f64, sink: &mut dyn FnMut(&Effect)) -> u64 {
        let mut steps = 0;
        while let Some(t) = self.next_time() {
            if t > horizon {
                break;
            }
            if !self.step(sink) {
                break;
            }
            steps += 1;
        }
        steps
    }

    /// Consume the driver: assert the pool drained and produce the
    /// normalized result. `defaults` parameterizes the idealized FPGA-only
    /// baseline (the paper always normalizes against *default* Table 6
    /// parameters).
    pub fn finish(self, defaults: &PlatformConfig) -> RunResult {
        // An aborted run stops mid-flight with live workers; its partial
        // metrics are only ever used to report how much work the abort
        // saved, never as a run's result.
        debug_assert!(
            self.aborted || self.sim.pool.is_empty(),
            "pool not drained at end of run"
        );
        RunResult {
            scheduler: self.policy.name(),
            ideal: IdealBaseline::for_work(self.sim.metrics.total_work, defaults),
            metrics: self.sim.metrics,
        }
    }

    fn observe(&mut self, obs: Observation, sink: &mut dyn FnMut(&Effect)) {
        let mut actions = std::mem::take(&mut self.actions);
        debug_assert!(actions.is_empty());
        self.policy.observe(obs, &self.sim, &mut actions);
        self.apply(&mut actions, sink);
        self.actions = actions;
    }

    fn apply(&mut self, actions: &mut Vec<Action>, sink: &mut dyn FnMut(&Effect)) {
        for action in actions.drain(..) {
            match action {
                Action::Alloc { kind, n, prewarmed } => {
                    for _ in 0..n {
                        let granted = if prewarmed {
                            self.sim.alloc_warm(kind)
                        } else {
                            self.sim.alloc(kind)
                        };
                        match granted {
                            Some(worker) => sink(&Effect::Allocated {
                                worker,
                                kind,
                                prewarmed,
                            }),
                            None => break, // cap reached
                        }
                    }
                }
                Action::Dispatch { req, to } | Action::Redispatch { req, to } => {
                    let worker = match to {
                        Target::Worker(w) => w,
                        Target::Fresh(kind) => match self.sim.alloc(kind) {
                            Some(w) => {
                                sink(&Effect::Allocated {
                                    worker: w,
                                    kind,
                                    prewarmed: false,
                                });
                                w
                            }
                            None => {
                                // Capped: best-effort onto the earliest-
                                // finishing live worker of any kind —
                                // O(log n) off the pool's ready index.
                                self.sim
                                    .pool
                                    .earliest_ready_any()
                                    .expect("no workers and worker cap reached")
                            }
                        },
                    };
                    let kind = self
                        .sim
                        .pool
                        .get(worker)
                        .expect("dispatch target vanished")
                        .kind;
                    let (finish, _seq) = self.sim.dispatch(req, worker);
                    sink(&Effect::Dispatched {
                        worker,
                        kind,
                        arrival: req.arrival,
                        size: req.size,
                        deadline: req.deadline,
                        finish,
                    });
                }
                Action::Retire { kind, n } => {
                    for worker in self.sim.retire_idle(kind, n) {
                        sink(&Effect::Retired { worker, kind });
                    }
                }
                Action::Shed { req } => {
                    // Refused admission: the request leaves the system
                    // here, never dispatched. A first offer still counts
                    // into `requests` (it did arrive); a shed retry was
                    // already counted at its first dispatch. Either way
                    // `requests == completions + abandoned + shed` holds
                    // once the run drains.
                    if req.attempt == 0 {
                        self.sim.metrics.requests += 1;
                    }
                    self.sim.metrics.shed += 1;
                    sink(&Effect::Shed {
                        arrival: req.arrival,
                        size: req.size,
                        deadline: req.deadline,
                        attempt: req.attempt,
                    });
                }
                // Only meaningful while answering IdleExpired (handled in
                // `handle_event`); stray keep-alives are inert.
                Action::KeepAlive { .. } => {}
                // Recovery layer: hold the retry in the event heap until
                // its backoff matures, then hand it back as RetryDue. A
                // `until` in the past fires at the current instant.
                Action::Defer { req, until } => {
                    let at = until.max(self.sim.now);
                    self.sim.events.push(at, Event::RetryDue { req });
                }
                Action::Timer { at, token } => {
                    let at = at.max(self.sim.now);
                    self.sim.events.push(at, Event::PolicyTimer { token });
                }
                Action::Abandon { req } => {
                    // Mirrors the kill-path abandonment accounting: the
                    // request leaves the system as an abandoned deadline
                    // miss, keeping `requests == completions + abandoned
                    // + shed` exact. (Retries were counted into `requests`
                    // at first dispatch; a fresh request abandoned here
                    // still counts in — both sides of the law move once.)
                    if req.attempt == 0 {
                        self.sim.metrics.requests += 1;
                    }
                    self.sim.metrics.abandoned += 1;
                    self.sim.metrics.deadline_misses += 1;
                }
                Action::Hedge { req, to } => self.apply_hedge(req, to, sink),
                Action::Quarantine { worker } => {
                    // Pure audit: the breaker lives in the recovery layer;
                    // the driver counts the opening and surfaces it on the
                    // effect stream. A vanished worker still counts — the
                    // breaker did open.
                    self.sim.metrics.quarantines += 1;
                    if let Some(w) = self.sim.pool.get(worker) {
                        sink(&Effect::Quarantined {
                            worker,
                            kind: w.kind,
                        });
                    }
                }
            }
        }
    }

    /// Apply [`Action::Hedge`]: if `req` is still in flight (matched
    /// bit-for-bit on arrival/size/deadline/attempt) and not already part
    /// of a hedge pair, dispatch a duplicate to `to` and link the two
    /// dispatches — first completion wins, the loser only frees its
    /// worker. No-op when the request is gone (already completed, drained,
    /// or abandoned): hedge timers race completions by design and the
    /// stale majority must cost nothing.
    fn apply_hedge(&mut self, req: Request, to: Target, sink: &mut dyn FnMut(&Effect)) {
        let primary_seq = self.sim.pool.iter_all().find_map(|w| {
            w.inflight.iter().find_map(|&(r, s)| {
                let matches = r == req
                    && !self.sim.hedge_partner.contains_key(&s)
                    && !self.sim.hedge_cancelled.contains(&s);
                if matches {
                    Some(s)
                } else {
                    None
                }
            })
        });
        let Some(primary_seq) = primary_seq else {
            return;
        };
        let worker = match to {
            Target::Worker(w) => w,
            Target::Fresh(kind) => match self.sim.alloc(kind) {
                Some(w) => {
                    sink(&Effect::Allocated {
                        worker: w,
                        kind,
                        prewarmed: false,
                    });
                    w
                }
                None => match self.sim.pool.earliest_ready_any() {
                    Some(w) => w,
                    None => return,
                },
            },
        };
        if self.sim.pool.get(worker).map_or(true, |w| !w.accepting()) {
            return;
        }
        // The duplicate's `attempt` sits one above the copy it shadows:
        // it skips the arrival-side counters in `dispatch` (the request
        // was already counted) and keeps fallback policies routing it
        // like the retry it morally is.
        let mut dup = req;
        dup.attempt = dup.attempt.saturating_add(1);
        let kind = self.sim.pool.get(worker).expect("hedge target").kind;
        let (finish, dup_seq) = self.sim.dispatch(dup, worker);
        self.sim.hedge_partner.insert(primary_seq, (dup_seq, false));
        self.sim.hedge_partner.insert(dup_seq, (primary_seq, true));
        self.sim.metrics.hedges += 1;
        sink(&Effect::Dispatched {
            worker,
            kind,
            arrival: dup.arrival,
            size: dup.size,
            deadline: dup.deadline,
            finish,
        });
    }

    fn handle_event(&mut self, event: Event, sink: &mut dyn FnMut(&Effect)) {
        match event {
            Event::SpinUpDone { worker, uid } => {
                match self.sim.pool.get(worker) {
                    None => return, // retired or killed before maturity
                    // Killed and the slot reused by a different worker.
                    Some(w) if w.uid != uid => return,
                    // Pre-warmed via alloc_warm; nothing to do.
                    Some(w) if w.state != WorkerState::SpinningUp => return,
                    Some(_) => {}
                }
                let now = self.sim.now;
                let went_idle = self.sim.pool.with_mut(worker, |w| {
                    w.state = WorkerState::Active;
                    if w.queued == 0 {
                        w.idle_since = now;
                        true
                    } else {
                        false
                    }
                });
                if went_idle {
                    self.sim.schedule_idle_timeout(worker);
                }
                self.observe(Observation::WorkerReady { worker }, sink);
            }
            Event::Completion {
                worker,
                uid,
                seq,
                arrival,
                deadline,
            } => {
                // A kill between dispatch and completion leaves this event
                // stale: the request was drained and re-offered (or
                // abandoned), so the completion must not double-book.
                match self.sim.pool.get(worker) {
                    Some(w) if w.uid == uid => {}
                    _ => return,
                }
                // Losing half of a settled hedge: the partner already
                // booked the request. Free the worker (the duplicate's
                // service really ran — its energy stays billed) and emit
                // nothing: no metrics, no effect, no observation.
                if self.sim.hedge_cancelled.remove(&seq) {
                    let (went_idle, _req, popped) = self.sim.complete_request(worker, false);
                    debug_assert_eq!(popped, seq, "hedge loser out of FIFO order");
                    if went_idle {
                        self.sim.schedule_idle_timeout(worker);
                    }
                    return;
                }
                // First completion of an open hedge pair wins: unlink both
                // halves and cancel the partner's eventual completion.
                let mut was_hedged = false;
                if let Some((partner, is_dup)) = self.sim.hedge_partner.remove(&seq) {
                    self.sim.hedge_partner.remove(&partner);
                    self.sim.hedge_cancelled.insert(partner);
                    if is_dup {
                        self.sim.metrics.hedge_wins += 1;
                    }
                    was_hedged = true;
                }
                let now = self.sim.now;
                let missed = now > deadline + 1e-9;
                if missed {
                    self.sim.metrics.deadline_misses += 1;
                }
                self.sim.completions_seen += 1;
                if self.sim.completions_seen % LATENCY_SAMPLE == 0 {
                    self.sim.metrics.latency.add(now - arrival);
                }
                let (went_idle, req, popped) = self.sim.complete_request(worker, true);
                debug_assert_eq!(popped, seq, "completion out of FIFO order");
                if !missed && (was_hedged || req.attempt > 0) {
                    self.sim.metrics.recovered_deadline_hits += 1;
                }
                if went_idle {
                    self.sim.schedule_idle_timeout(worker);
                }
                let kind = self.sim.pool.get(worker).expect("completing worker").kind;
                sink(&Effect::Completed {
                    worker,
                    kind,
                    arrival,
                    finish: now,
                });
                self.observe(Observation::Completion { worker, req }, sink);
            }
            Event::IdleTimeout {
                worker,
                uid,
                generation,
            } => {
                let now = self.sim.now;
                let mature = match self.sim.pool.get(worker) {
                    Some(w) => {
                        w.uid == uid
                            && w.state == WorkerState::Active
                            && w.queued == 0
                            && w.generation == generation
                            && w.busy_until <= now
                    }
                    None => false,
                };
                if mature {
                    // Consult the policy: KeepAlive holds the worker for
                    // another timeout window (pinned fleet / standing
                    // headroom), anything else lets it spin down.
                    let mut actions = std::mem::take(&mut self.actions);
                    self.policy
                        .observe(Observation::IdleExpired { worker }, &self.sim, &mut actions);
                    let keep = actions
                        .iter()
                        .any(|a| matches!(a, Action::KeepAlive { worker: w } if *w == worker));
                    actions.retain(|a| !matches!(a, Action::KeepAlive { .. }));
                    self.apply(&mut actions, sink);
                    self.actions = actions;
                    if keep {
                        self.sim.schedule_idle_timeout(worker);
                        sink(&Effect::KeptAlive { worker });
                    } else {
                        // Re-check after applying the policy's actions: a
                        // Retire/Dispatch in the same batch may have already
                        // retired this worker or handed it new work.
                        let still_idle = self.sim.pool.get(worker).map_or(false, |w| {
                            w.state == WorkerState::Active
                                && w.queued == 0
                                && w.busy_until <= now
                        });
                        if still_idle {
                            let kind = self.sim.pool.get(worker).expect("idle worker").kind;
                            self.sim.retire(worker);
                            sink(&Effect::Retired { worker, kind });
                        }
                    }
                }
            }
            Event::SpinDownDone { worker, uid } => {
                // Scenario kills can't target spinning-down workers, so a
                // mismatch can only mean slot reuse after a kill elsewhere
                // in the lifecycle — drop the stale event.
                match self.sim.pool.get(worker) {
                    Some(w) if w.uid == uid => {}
                    _ => return,
                }
                let w = self.sim.pool.remove(worker);
                debug_assert_eq!(w.state, WorkerState::SpinningDown);
                let params = self.sim.cfg.platform.params(w.kind);
                let lifetime = self.sim.now - w.alloc_time;
                let cost = if self.sim.kind_is_spot(w.kind) {
                    params.cost_per_sec()
                        * (self.sim.price_integral_now(w.kind) - w.cost_basis)
                } else {
                    lifetime * params.cost_per_sec()
                };
                match w.kind {
                    WorkerKind::Cpu => self.sim.metrics.cpu_cost += cost,
                    WorkerKind::Fpga => self.sim.metrics.fpga_cost += cost,
                }
                self.observe(
                    Observation::Dealloc {
                        kind: w.kind,
                        lifetime,
                        peers_at_alloc: w.peers_at_alloc,
                    },
                    sink,
                );
            }
            Event::PriceTick { kind, price } => {
                let now = self.sim.now;
                if let Some(sc) = self.sim.scenario.as_mut() {
                    let k = kind.index();
                    sc.integral[k] += sc.price[k] * (now - sc.last_t[k]);
                    sc.last_t[k] = now;
                    sc.price[k] = price;
                }
                self.observe(Observation::PriceTick { kind, price }, sink);
            }
            Event::Preempted { kind, victim_draw } => {
                self.apply_fault(kind, victim_draw, false, sink);
            }
            Event::WorkerFailed { kind, victim_draw } => {
                self.apply_fault(kind, victim_draw, true, sink);
            }
            Event::RetryDue { req } => {
                self.observe(Observation::RetryDue { req }, sink);
            }
            Event::PolicyTimer { token } => {
                self.observe(Observation::Timer { token }, sink);
            }
        }
    }

    /// Apply one planned fault: pick the victim over the kind's live
    /// accepting workers (no-op when none exist — a planned strike against
    /// an empty pool hits nothing), kill it, and route every drained
    /// in-flight request: re-offer it to the policy as an `Arrival` with
    /// `attempt` incremented, unless its retry budget or deadline is
    /// already exhausted — then record it as an abandoned deadline miss.
    fn apply_fault(
        &mut self,
        kind: WorkerKind,
        victim_draw: f64,
        failure: bool,
        sink: &mut dyn FnMut(&Effect),
    ) {
        let victims: Vec<WorkerId> = self
            .sim
            .pool
            .iter_kind(kind)
            .filter(|w| w.accepting())
            .map(|w| w.id)
            .collect();
        if victims.is_empty() {
            return;
        }
        let idx = ((victim_draw * victims.len() as f64) as usize).min(victims.len() - 1);
        let victim = victims[idx];
        let lost = self.sim.kill(victim);
        if failure {
            self.sim.metrics.worker_failures += 1;
        } else {
            self.sim.metrics.preemptions += 1;
        }
        sink(&Effect::Killed {
            worker: victim,
            kind,
            failure,
        });
        self.observe(
            Observation::Preempted {
                worker: victim,
                kind,
                failure,
                lost: lost.len() as u32,
            },
            sink,
        );
        let budget = self
            .sim
            .scenario
            .as_ref()
            .map_or(0, |s| s.cfg.retry_budget);
        for (mut req, seq) in lost {
            // Hedge interplay: a drained copy whose partner already won
            // was completed through that partner — drop it silently. A
            // drained copy whose partner is still running just unlinks
            // the pair: the survivor reverts to an ordinary dispatch and
            // will book the completion, so re-offering here would
            // duplicate the request. (If both copies sit in this same
            // drain, the first unlinks and the second falls through to
            // the normal retry/abandon path — exactly one continuation.)
            if self.sim.hedge_cancelled.remove(&seq) {
                continue;
            }
            if let Some((partner, _)) = self.sim.hedge_partner.remove(&seq) {
                self.sim.hedge_partner.remove(&partner);
                continue;
            }
            let now = self.sim.now;
            // Deadline-aware abandonment: if even an immediate dispatch
            // onto the fastest kind can't finish in time, don't waste the
            // retry on a guaranteed miss.
            let min_svc = WorkerKind::ALL
                .iter()
                .map(|&k| self.sim.service_time(k, req.size))
                .fold(f64::INFINITY, f64::min);
            if req.attempt >= budget || now + min_svc > req.deadline {
                self.sim.metrics.abandoned += 1;
                self.sim.metrics.deadline_misses += 1;
                self.observe(Observation::Abandoned { req }, sink);
            } else {
                req.attempt += 1;
                self.sim.metrics.redispatches += 1;
                self.observe(Observation::Arrival { req }, sink);
            }
        }
    }
}

/// Run `policy` over `trace` under `cfg`; returns normalized results.
/// `defaults` parameterizes the idealized FPGA-only baseline (the paper
/// always normalizes against *default* Table 6 parameters).
pub fn run(
    trace: &AppTrace,
    cfg: SimConfig,
    defaults: &PlatformConfig,
    policy: &mut dyn Policy,
) -> RunResult {
    run_with_sink(trace, cfg, defaults, policy, &mut |_| {})
}

/// Like [`run`], reporting every applied [`Effect`] to `sink` — the audit
/// stream the driver-parity suite compares against the real-time driver.
pub fn run_with_sink(
    trace: &AppTrace,
    cfg: SimConfig,
    defaults: &PlatformConfig,
    policy: &mut dyn Policy,
    sink: &mut dyn FnMut(&Effect),
) -> RunResult {
    run_source_with_sink(Box::new(trace.source()), cfg, defaults, policy, sink)
}

/// Run `policy` over a streaming arrival source. Memory is bounded by
/// the worker pool and pending events, not the stream length — the entry
/// point for million-request replays and CSV streams too large to load.
pub fn run_source(
    source: Box<dyn ArrivalSource + '_>,
    cfg: SimConfig,
    defaults: &PlatformConfig,
    policy: &mut dyn Policy,
) -> RunResult {
    run_source_with_sink(source, cfg, defaults, policy, &mut |_| {})
}

/// Run `policy` over a streaming source with `scenario` attached: the
/// fault plan derived from `(seed_base, seed)` is replayed against the
/// run, spot kinds bill against their price path, and killed in-flight
/// requests are re-dispatched or abandoned per the scenario's retry
/// budget. With the fault-free pack this is bit-identical to
/// [`run_source`].
pub fn run_source_scenario<'a>(
    source: Box<dyn ArrivalSource + 'a>,
    cfg: SimConfig,
    defaults: &PlatformConfig,
    policy: &'a mut dyn Policy,
    scenario: &ScenarioConfig,
    seed_base: u64,
    seed: u64,
) -> RunResult {
    let mut driver = Driver::from_source(source, cfg, policy);
    driver.attach_scenario(scenario, seed_base, seed);
    let sink = &mut |_: &Effect| {};
    driver.start(sink);
    while driver.step(sink) {}
    driver.finish(defaults)
}

/// A run that may have stopped at its miss budget (see
/// [`run_source_bounded`]). When `aborted` is true the metrics are the
/// partial tally up to the abort point — enough to report how much of
/// the trace was saved (`metrics.requests` arrivals were processed),
/// never a substitute for a full run's result.
pub struct BoundedRun {
    pub result: RunResult,
    pub aborted: bool,
}

/// Run `policy` over a streaming source with the early-abort stop
/// condition armed (when the source's length is known): the pass halts
/// the instant its deadline misses provably exceed `miss_tolerance` of
/// the full run. `aborted == true` ⟺ the full pass would have been
/// infeasible; `aborted == false` yields a result bit-identical to
/// [`run_source`] (a feasible run never reaches its budget, and the
/// budget check is pure observation). The fitting searches run every
/// candidate through this, so infeasible probes touch only a prefix of
/// the trace.
pub fn run_source_bounded<'a>(
    source: Box<dyn ArrivalSource + 'a>,
    cfg: SimConfig,
    defaults: &PlatformConfig,
    policy: &'a mut dyn Policy,
    miss_tolerance: f64,
) -> BoundedRun {
    let mut driver = Driver::from_source(source, cfg, policy);
    driver.abort_on_excess_misses(miss_tolerance);
    let sink = &mut |_: &Effect| {};
    driver.start(sink);
    while driver.step(sink) {}
    let aborted = driver.aborted();
    BoundedRun {
        result: driver.finish(defaults),
        aborted,
    }
}

/// Run N `(source, policy)` pairs through their streams in lockstep,
/// each with the early-abort miss budget armed for `miss_tolerance` —
/// the multi-candidate engine behind the §5.1 lockstep fitting searches.
///
/// Every driver is the exact [`run_source_bounded`] loop: stepping is
/// interleaved *across* drivers, but no simulation state is shared, so
/// each returned [`BoundedRun`] is bit-identical to running that
/// `(source, policy)` pair serially. The interleaving exists purely to
/// bound memory when the sources are consumers of one [`tee`] fan-out:
/// drivers advance to a common arrivals-pulled frontier before any
/// driver pulls further, so the shared buffer holds O(1) arrivals
/// (fastest-to-slowest spread ≤ 1 plus one look-ahead each).
///
/// A driver that aborts at its miss budget — or whose stream exhausts —
/// is finalized immediately and its source dropped, releasing its stake
/// in the tee buffer; the survivors keep streaming. This is what lets
/// infeasible candidates fall out of a fitting batch mid-pass at the
/// same abort point they would hit serially.
///
/// [`tee`]: crate::trace::tee
pub fn run_sources_lockstep<'a>(
    sources: Vec<Box<dyn ArrivalSource + 'a>>,
    cfg: &SimConfig,
    defaults: &PlatformConfig,
    policies: &'a mut [Box<dyn Policy>],
    miss_tolerance: f64,
) -> Vec<BoundedRun> {
    assert_eq!(
        sources.len(),
        policies.len(),
        "lockstep needs one policy per source"
    );
    let sink = &mut |_: &Effect| {};
    let mut drivers: Vec<Option<Driver>> = sources
        .into_iter()
        .zip(policies.iter_mut())
        .map(|(src, policy)| {
            let mut d = Driver::from_source(src, cfg.clone(), policy.as_mut());
            d.abort_on_excess_misses(miss_tolerance);
            d.start(sink);
            Some(d)
        })
        .collect();
    let mut out: Vec<Option<BoundedRun>> = drivers.iter().map(|_| None).collect();
    loop {
        // Frontier: the least arrivals-pulled count among drivers still
        // consuming their stream. A driver whose stream is exhausted no
        // longer holds a buffer stake — drain it to completion now (its
        // remaining events are its own).
        let mut frontier: Option<u64> = None;
        for slot in 0..drivers.len() {
            let Some(d) = drivers[slot].as_mut() else { continue };
            if d.source_exhausted() {
                while d.step(sink) {}
                let d = drivers[slot].take().expect("slot emptied mid-drain");
                out[slot] = Some(BoundedRun {
                    aborted: d.aborted(),
                    result: d.finish(defaults),
                });
            } else {
                let p = d.arrivals_pulled();
                frontier = Some(frontier.map_or(p, |f| f.min(p)));
            }
        }
        let Some(frontier) = frontier else { break };
        // Advance every at-frontier driver until it pulls past the
        // frontier, exhausts its stream, or stops (abort). Each step here
        // is exactly the step a serial run would take next.
        for slot in 0..drivers.len() {
            let Some(d) = drivers[slot].as_mut() else { continue };
            let mut stopped = false;
            while !stopped && !d.source_exhausted() && d.arrivals_pulled() <= frontier {
                stopped = !d.step(sink);
            }
            if stopped {
                let d = drivers[slot].take().expect("slot emptied mid-step");
                out[slot] = Some(BoundedRun {
                    aborted: d.aborted(),
                    result: d.finish(defaults),
                });
            }
        }
    }
    out.into_iter()
        .map(|r| r.expect("every lockstep driver is finalized before exit"))
        .collect()
}

/// Like [`run_source`], reporting every applied [`Effect`] to `sink`.
pub fn run_source_with_sink<'a>(
    source: Box<dyn ArrivalSource + 'a>,
    cfg: SimConfig,
    defaults: &PlatformConfig,
    policy: &'a mut dyn Policy,
    sink: &mut dyn FnMut(&Effect),
) -> RunResult {
    let mut driver = Driver::from_source(source, cfg, policy);
    driver.start(sink);
    while driver.step(sink) {}
    driver.finish(defaults)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::Target;
    use crate::trace::{AppTrace, Arrival};

    /// Trivial reactive policy: one new CPU per request (serverless 1:1).
    /// Exercises the full worker lifecycle.
    struct OnePerRequest;
    impl Policy for OnePerRequest {
        fn name(&self) -> String {
            "one-per-request".into()
        }
        fn interval(&self) -> f64 {
            f64::INFINITY
        }
        fn observe(&mut self, obs: Observation, _view: &dyn PolicyView, out: &mut Vec<Action>) {
            if let Observation::Arrival { req } = obs {
                out.push(Action::Dispatch {
                    req,
                    to: Target::Fresh(WorkerKind::Cpu),
                });
            }
        }
    }

    /// Policy that packs everything onto a single pre-allocated FPGA.
    struct OneFpga;
    impl Policy for OneFpga {
        fn name(&self) -> String {
            "one-fpga".into()
        }
        fn interval(&self) -> f64 {
            f64::INFINITY
        }
        fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
            match obs {
                Observation::Start => out.push(Action::Alloc {
                    kind: WorkerKind::Fpga,
                    n: 1,
                    prewarmed: false,
                }),
                Observation::Arrival { req } => {
                    let id = view.live_ids(WorkerKind::Fpga)[0];
                    out.push(Action::Dispatch {
                        req,
                        to: Target::Worker(id),
                    });
                }
                _ => {}
            }
        }
    }

    fn mini_trace(n: usize, gap: f64, size: f64) -> AppTrace {
        let arrivals: Vec<Arrival> = (0..n)
            .map(|i| Arrival {
                time: i as f64 * gap,
                size,
            })
            .collect();
        let duration = n as f64 * gap;
        AppTrace::new("mini", arrivals, duration)
    }

    fn defaults() -> PlatformConfig {
        PlatformConfig::paper_default()
    }

    #[test]
    fn one_per_request_accounting() {
        let trace = mini_trace(10, 1.0, 0.010);
        let cfg = SimConfig::paper_default();
        let r = run(&trace, cfg.clone(), &defaults(), &mut OnePerRequest);
        let m = &r.metrics;
        assert_eq!(m.requests, 10);
        assert_eq!(m.on_cpu, 10);
        assert_eq!(m.cpu_spinups, 10);
        assert_eq!(m.deadline_misses, 0);
        // busy energy: 10 * 0.010s * 150W = 15 J
        assert!((m.cpu_energy.busy - 15.0).abs() < 1e-9);
        // alloc energy: 10 * 0.75 J
        assert!((m.cpu_energy.alloc - 7.5).abs() < 1e-9);
        // idle energy: each worker idles for the cpu idle timeout
        let expected_idle = 10.0 * cfg.cpu_idle_timeout * 30.0;
        assert!(
            (m.cpu_energy.idle - expected_idle).abs() < 1e-6,
            "idle {} vs {}",
            m.cpu_energy.idle,
            expected_idle
        );
        // cost: lifetime = spin_up + svc + timeout + spin_down each
        let life = 0.005 + 0.010 + cfg.cpu_idle_timeout + 0.005;
        assert!((m.cpu_cost - 10.0 * life * 0.668 / 3600.0).abs() < 1e-9);
        assert_eq!(m.fpga_spinups, 0);
    }

    #[test]
    fn single_fpga_packs_all() {
        // 10ms requests every 6ms on a 2x FPGA (5ms service): queue never
        // grows unboundedly; all served by one FPGA. Arrivals start after
        // the 10s spin-up so deadlines are reachable.
        let arrivals: Vec<Arrival> = (0..100)
            .map(|i| Arrival {
                time: 10.5 + i as f64 * 0.006,
                size: 0.010,
            })
            .collect();
        let trace = AppTrace::new("mini", arrivals, 11.2);
        let cfg = SimConfig::paper_default();
        let r = run(&trace, cfg, &defaults(), &mut OneFpga);
        let m = &r.metrics;
        assert_eq!(m.on_fpga, 100);
        assert_eq!(m.fpga_spinups, 1);
        assert_eq!(m.deadline_misses, 0);
        // busy energy = 100 * 0.005 * 50
        assert!((m.fpga_energy.busy - 25.0).abs() < 1e-9);
        assert!((m.fpga_energy.alloc - 500.0).abs() < 1e-9);
        assert_eq!(m.peak_fpgas, 1);
    }

    #[test]
    fn deadline_miss_detected() {
        // Single FPGA; burst of simultaneous arrivals with tight deadlines:
        // the tail of the queue must miss.
        let arrivals: Vec<Arrival> = (0..20)
            .map(|_| Arrival { time: 0.0, size: 0.010 })
            .collect();
        let trace = AppTrace::new("burst", arrivals, 1.0);
        let cfg = SimConfig::paper_default();
        let r = run(&trace, cfg, &defaults(), &mut OneFpga);
        // deadline = 0.1; spin_up 10s dominates → all miss.
        assert_eq!(r.metrics.deadline_misses, 20);
    }

    #[test]
    fn energy_conservation_identity() {
        // Total energy must equal the integral implied by component sums:
        // busy = total service x busy power, alloc = spinups x spin-up
        // energy, dealloc = spinups x spin-down energy (every worker dies).
        let trace = mini_trace(50, 0.3, 0.020);
        let cfg = SimConfig::paper_default();
        let r = run(&trace, cfg, &defaults(), &mut OnePerRequest);
        let m = &r.metrics;
        assert!((m.cpu_energy.busy - 50.0 * 0.020 * 150.0).abs() < 1e-9);
        assert!((m.cpu_energy.alloc - 50.0 * 0.75).abs() < 1e-9);
        assert!((m.cpu_energy.dealloc - 50.0 * 0.005 * 150.0).abs() < 1e-9);
        assert!((m.total_work - 50.0 * 0.020).abs() < 1e-9);
    }

    #[test]
    fn idle_timeout_respects_new_work() {
        // Requests arrive every 0.5 * timeout: worker should never retire
        // between them when timeout allows bridging.
        let mut cfg = SimConfig::paper_default();
        cfg.cpu_idle_timeout = 1.0;
        let trace = mini_trace(10, 0.5, 0.010);
        let r = run(&trace, cfg, &defaults(), &mut ReuseCpu);
        assert_eq!(r.metrics.cpu_spinups, 1, "worker should be reused");
    }

    /// Reuses the first accepting CPU if alive, else allocates fresh.
    struct ReuseCpu;
    impl Policy for ReuseCpu {
        fn name(&self) -> String {
            "reuse-cpu".into()
        }
        fn interval(&self) -> f64 {
            f64::INFINITY
        }
        fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
            if let Observation::Arrival { req } = obs {
                let alive = view
                    .live_ids(WorkerKind::Cpu)
                    .into_iter()
                    .find(|&id| view.worker(id).map_or(false, |w| w.accepting()));
                let to = match alive {
                    Some(id) => Target::Worker(id),
                    None => Target::Fresh(WorkerKind::Cpu),
                };
                out.push(Action::Dispatch { req, to });
            }
        }
    }

    #[test]
    fn caps_enforced() {
        let mut cfg = SimConfig::paper_default();
        cfg.max_cpus = Some(2);
        let trace = mini_trace(10, 0.0001, 0.010);
        let r = run(&trace, cfg, &defaults(), &mut OnePerRequest);
        assert!(r.metrics.peak_cpus <= 2);
        assert_eq!(r.metrics.requests, 10);
    }

    #[test]
    fn ticks_fire_while_trace_live() {
        struct TickCounter {
            ticks: u32,
            last_index: usize,
        }
        impl Policy for TickCounter {
            fn name(&self) -> String {
                "ticks".into()
            }
            fn interval(&self) -> f64 {
                1.0
            }
            fn observe(&mut self, obs: Observation, _view: &dyn PolicyView, out: &mut Vec<Action>) {
                match obs {
                    Observation::Tick { index, .. } => {
                        self.ticks += 1;
                        self.last_index = index;
                    }
                    Observation::Arrival { req } => out.push(Action::Dispatch {
                        req,
                        to: Target::Fresh(WorkerKind::Cpu),
                    }),
                    _ => {}
                }
            }
        }
        let trace = mini_trace(5, 2.0, 0.010); // duration 10
        let mut s = TickCounter { ticks: 0, last_index: 0 };
        run(&trace, SimConfig::paper_default(), &defaults(), &mut s);
        assert_eq!(s.ticks, 10); // t = 1..=10
        assert_eq!(s.last_index, 10); // Tick index k <=> t = k * T_s
    }

    #[test]
    fn warm_alloc_schedules_no_spinup_event() {
        // A warm alloc is Active immediately, so its SpinUpDone would be a
        // guaranteed no-op — it must not be pushed at all (one dead heap
        // entry per worker of a pre-warmed fpga-static fleet otherwise).
        let mut sim = SimState::new(SimConfig::paper_default());
        let id = sim.alloc_warm(WorkerKind::Fpga).unwrap();
        assert_eq!(sim.pool.get(id).unwrap().state, WorkerState::Active);
        assert_eq!(sim.events.len(), 1, "only the idle timeout is pending");
        // A cold alloc still schedules its SpinUpDone.
        sim.alloc(WorkerKind::Fpga).unwrap();
        assert_eq!(sim.events.len(), 2);
    }

    #[test]
    fn bounded_run_aborts_iff_infeasible() {
        // 20 simultaneous arrivals on one FPGA behind a 10s spin-up: every
        // request misses. Any tolerance < 1 must abort; tolerance 1 must
        // run to completion and match the unbounded run bit-for-bit.
        let arrivals: Vec<Arrival> = (0..20)
            .map(|_| Arrival { time: 0.0, size: 0.010 })
            .collect();
        let trace = AppTrace::new("burst", arrivals, 1.0);
        let cfg = SimConfig::paper_default();

        let full = run(&trace, cfg.clone(), &defaults(), &mut OneFpga);
        assert_eq!(full.metrics.deadline_misses, 20);

        let b = run_source_bounded(
            Box::new(trace.source()),
            cfg.clone(),
            &defaults(),
            &mut OneFpga,
            0.25,
        );
        assert!(b.aborted, "an infeasible pass must abort");
        // budget = 5 misses; the abort fires on the first step after the
        // 6th — far short of the 20 completions a full pass processes.
        assert!(b.result.metrics.deadline_misses <= 7);

        let f = run_source_bounded(
            Box::new(trace.source()),
            cfg,
            &defaults(),
            &mut OneFpga,
            1.0,
        );
        assert!(!f.aborted, "a feasible pass never reaches its budget");
        assert_eq!(f.result.metrics.deadline_misses, full.metrics.deadline_misses);
        assert_eq!(f.result.metrics.requests, full.metrics.requests);
        assert_eq!(f.result.metrics.total_energy(), full.metrics.total_energy());
        assert_eq!(f.result.metrics.total_cost(), full.metrics.total_cost());
    }

    #[test]
    fn bounded_run_without_len_hint_runs_full() {
        // A generator source (no len_hint) cannot arm the abort: the run
        // must complete and match the materialized pass.
        use crate::trace::synthetic_source;
        use crate::util::rng::Rng;
        let src = synthetic_source("g", Rng::new(3), 0.6, 60.0, 50.0, 0.010, 60.0);
        assert_eq!(crate::trace::ArrivalSource::len_hint(&src), None);
        let b = run_source_bounded(
            Box::new(src),
            SimConfig::paper_default(),
            &defaults(),
            &mut OnePerRequest,
            0.0,
        );
        assert!(!b.aborted);
        assert!(b.result.metrics.requests > 0);
    }

    #[test]
    fn driver_validates_len_hint_exactness() {
        // A source that lies about its count must fail loudly at
        // exhaustion, not silently skew the abort budget.
        use crate::trace::KnownLen;
        let trace = mini_trace(3, 1.0, 0.010);
        let lying = KnownLen::new(Box::new(trace.clone().into_source()), 5);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            run_source(
                Box::new(lying),
                SimConfig::paper_default(),
                &defaults(),
                &mut OnePerRequest,
            )
        }));
        assert!(result.is_err(), "miscounted len_hint must panic");
    }

    #[test]
    fn lockstep_runs_are_bit_identical_to_serial_bounded_runs() {
        // Three policies over one teed stream — one infeasible at any
        // tolerance < 1 (single FPGA behind a 10s spin-up), two feasible
        // (one-CPU-per-request) — must each produce exactly the serial
        // run_source_bounded result, including the abort.
        let arrivals: Vec<Arrival> = (0..30)
            .map(|i| Arrival { time: 0.01 * i as f64, size: 0.010 })
            .collect();
        let trace = AppTrace::new("ls", arrivals, 1.0);
        let cfg = SimConfig::paper_default();
        let tol = 0.25;

        let serial: Vec<BoundedRun> = vec![
            run_source_bounded(
                Box::new(trace.source()),
                cfg.clone(),
                &defaults(),
                &mut OneFpga,
                tol,
            ),
            run_source_bounded(
                Box::new(trace.source()),
                cfg.clone(),
                &defaults(),
                &mut OnePerRequest,
                tol,
            ),
            run_source_bounded(
                Box::new(trace.source()),
                cfg.clone(),
                &defaults(),
                &mut OnePerRequest,
                tol,
            ),
        ];
        assert!(serial[0].aborted, "OneFpga must be infeasible here");
        assert!(!serial[1].aborted);

        let consumers = crate::trace::tee(Box::new(trace.source()), 3);
        let sources: Vec<Box<dyn ArrivalSource + '_>> = consumers
            .into_iter()
            .map(|c| Box::new(c) as Box<dyn ArrivalSource + '_>)
            .collect();
        let mut policies: Vec<Box<dyn Policy>> = vec![
            Box::new(OneFpga),
            Box::new(OnePerRequest),
            Box::new(OnePerRequest),
        ];
        let lockstep = run_sources_lockstep(sources, &cfg, &defaults(), &mut policies, tol);
        assert_eq!(lockstep.len(), 3);
        for (i, (l, s)) in lockstep.iter().zip(&serial).enumerate() {
            assert_eq!(l.aborted, s.aborted, "driver {i}: abort flag");
            assert_eq!(
                l.result.metrics.requests, s.result.metrics.requests,
                "driver {i}: requests"
            );
            assert_eq!(
                l.result.metrics.deadline_misses, s.result.metrics.deadline_misses,
                "driver {i}: misses"
            );
            assert_eq!(
                l.result.metrics.total_energy(),
                s.result.metrics.total_energy(),
                "driver {i}: energy"
            );
            assert_eq!(
                l.result.metrics.total_cost(),
                s.result.metrics.total_cost(),
                "driver {i}: cost"
            );
        }
    }

    #[test]
    fn effect_stream_covers_run() {
        let trace = mini_trace(10, 1.0, 0.010);
        let mut dispatched = 0u32;
        let mut allocated = 0u32;
        let mut retired = 0u32;
        let mut completed = 0u32;
        run_with_sink(
            &trace,
            SimConfig::paper_default(),
            &defaults(),
            &mut OnePerRequest,
            &mut |e| match e {
                Effect::Dispatched { .. } => dispatched += 1,
                Effect::Allocated { .. } => allocated += 1,
                Effect::Retired { .. } => retired += 1,
                Effect::KeptAlive { .. } => {}
                Effect::Completed { .. } => completed += 1,
                Effect::Killed { .. } => panic!("no scenario attached"),
                Effect::Shed { .. } => panic!("no admission cap armed"),
                Effect::Quarantined { .. } => panic!("no recovery layer attached"),
            },
        );
        assert_eq!(dispatched, 10);
        assert_eq!(completed, 10, "every dispatch must emit its completion");
        assert_eq!(allocated, 10);
        assert_eq!(retired, 10, "every worker must retire by drain");
    }

    // ---- scenario-path units: kill, retry, abandonment, spot billing ----

    use crate::scenario::{Fault, FaultPlan, PlannedFault, ScenarioConfig};

    /// One preemption strike against the FPGA pool at `t`.
    fn strike_plan(t: f64) -> FaultPlan {
        FaultPlan {
            faults: vec![PlannedFault {
                time: t,
                fault: Fault::Preemption {
                    kind: WorkerKind::Fpga,
                    victim_draw: 0.0,
                },
            }],
        }
    }

    fn scenario_run(
        trace: &AppTrace,
        cfg: SimConfig,
        scen: &ScenarioConfig,
        plan: &FaultPlan,
        policy: &mut dyn Policy,
    ) -> (RunResult, u32) {
        let mut driver = Driver::from_source(Box::new(trace.source()), cfg, policy);
        driver.attach_plan(scen, plan);
        let mut killed = 0u32;
        let sink = &mut |e: &Effect| {
            if matches!(e, Effect::Killed { .. }) {
                killed += 1;
            }
        };
        driver.start(sink);
        while driver.step(sink) {}
        (driver.finish(&defaults()), killed)
    }

    /// Zero spin-up/spin-down so kill/retry timing is easy to reason about.
    fn instant_cfg() -> SimConfig {
        let mut cfg = SimConfig::paper_default();
        cfg.platform.fpga.spin_up = 0.0;
        cfg.platform.fpga.spin_down = 0.0;
        cfg.platform.cpu.spin_up = 0.0;
        cfg.platform.cpu.spin_down = 0.0;
        cfg
    }

    /// Dispatches every arrival (fresh or retried) to an FPGA: reuse the
    /// first accepting one, else allocate fresh.
    struct ReuseFpga;
    impl Policy for ReuseFpga {
        fn name(&self) -> String {
            "reuse-fpga".into()
        }
        fn interval(&self) -> f64 {
            f64::INFINITY
        }
        fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
            if let Observation::Arrival { req } = obs {
                let alive = view
                    .live_ids(WorkerKind::Fpga)
                    .into_iter()
                    .find(|&id| view.worker(id).map_or(false, |w| w.accepting()));
                let to = match alive {
                    Some(id) => Target::Worker(id),
                    None => Target::Fresh(WorkerKind::Fpga),
                };
                out.push(Action::Dispatch { req, to });
            }
        }
    }

    #[test]
    fn kill_redispatches_inflight_within_budget() {
        // One 1s request at t=0 on an instant FPGA; preemption at t=0.2
        // kills it mid-flight. The retry (attempt 1) lands on a fresh FPGA
        // and completes at 0.2 + 0.5 (2x speedup service restarts).
        let trace = AppTrace::new(
            "one",
            vec![Arrival { time: 0.0, size: 1.0 }],
            50.0, // window long enough for the strike to land pre-drain
        );
        let scen = ScenarioConfig::mild();
        let (r, killed) = scenario_run(
            &trace,
            instant_cfg(),
            &scen,
            &strike_plan(0.2),
            &mut ReuseFpga,
        );
        let m = &r.metrics;
        assert_eq!(killed, 1);
        assert_eq!(m.preemptions, 1);
        assert_eq!(m.worker_failures, 0);
        assert_eq!(m.redispatches, 1);
        assert_eq!(m.abandoned, 0);
        assert_eq!(m.requests, 1, "retry must not recount the arrival");
        assert_eq!(m.completions, 1);
        assert_eq!(m.on_fpga, 1);
        // 0.2s executed on the killed worker and thrown away.
        assert!((m.work_lost - 0.2).abs() < 1e-9, "work_lost = {}", m.work_lost);
        // Busy energy = (0.2 wasted + 0.5 full retry) × 50 W: the kill
        // refunded the unexecuted 0.3s of the first dispatch.
        assert!((m.fpga_energy.busy - 0.7 * 50.0).abs() < 1e-9);
        assert_eq!(m.deadline_misses, 0, "deadline 10s is easily met");
    }

    #[test]
    fn kill_abandons_when_budget_exhausted() {
        let trace = AppTrace::new("one", vec![Arrival { time: 0.0, size: 1.0 }], 50.0);
        let mut scen = ScenarioConfig::mild();
        scen.retry_budget = 0;
        let (r, killed) = scenario_run(
            &trace,
            instant_cfg(),
            &scen,
            &strike_plan(0.2),
            &mut ReuseFpga,
        );
        let m = &r.metrics;
        assert_eq!(killed, 1);
        assert_eq!(m.abandoned, 1);
        assert_eq!(m.redispatches, 0);
        assert_eq!(m.completions, 0);
        assert_eq!(m.deadline_misses, 1, "an abandoned request is a miss");
        assert_eq!(
            m.requests,
            m.completions + m.abandoned,
            "arrival conservation"
        );
    }

    #[test]
    fn kill_abandons_unmeetable_deadlines_early() {
        // Deadline 0.4 (factor-scaled): after a kill at t=0.35 even an
        // immediate retry (min service 0.5 on the FPGA, 1.0 on CPU) can't
        // finish by 0.4 — the driver must abandon instead of burning the
        // retry on a guaranteed miss.
        let mut cfg = instant_cfg();
        cfg.deadline_factor = 0.4;
        let trace = AppTrace::new("one", vec![Arrival { time: 0.0, size: 1.0 }], 50.0);
        let scen = ScenarioConfig::mild(); // budget 3: only the deadline gates
        let (r, _) = scenario_run(&trace, cfg, &scen, &strike_plan(0.35), &mut ReuseFpga);
        let m = &r.metrics;
        assert_eq!(m.abandoned, 1);
        assert_eq!(m.redispatches, 0);
        assert_eq!(m.deadline_misses, 1);
    }

    #[test]
    fn strike_against_empty_pool_is_noop() {
        let trace = AppTrace::new("one", vec![Arrival { time: 1.0, size: 0.010 }], 50.0);
        let scen = ScenarioConfig::mild();
        // Strike at t=0.5: nothing allocated yet.
        let (r, killed) = scenario_run(
            &trace,
            instant_cfg(),
            &scen,
            &strike_plan(0.5),
            &mut ReuseFpga,
        );
        assert_eq!(killed, 0);
        assert_eq!(r.metrics.preemptions, 0);
        assert_eq!(r.metrics.completions, 1);
    }

    #[test]
    fn spot_billing_integrates_price_path() {
        // Constant price 2.0 from t=0 (one tick) on a spot FPGA: cost must
        // be exactly 2× the on-demand run.
        let trace = AppTrace::new("one", vec![Arrival { time: 0.0, size: 1.0 }], 50.0);
        let mut scen = ScenarioConfig::mild();
        scen.kinds[WorkerKind::Fpga.index()].spot = true;
        let plan = FaultPlan {
            faults: vec![PlannedFault {
                time: 0.0,
                fault: Fault::PriceTick {
                    kind: WorkerKind::Fpga,
                    price: 2.0,
                },
            }],
        };
        let (r, _) = scenario_run(&trace, instant_cfg(), &scen, &plan, &mut ReuseFpga);
        let plain = run(
            &AppTrace::new("one", vec![Arrival { time: 0.0, size: 1.0 }], 50.0),
            instant_cfg(),
            &defaults(),
            &mut ReuseFpga,
        );
        assert!(
            (r.metrics.fpga_cost - 2.0 * plain.metrics.fpga_cost).abs() < 1e-9,
            "spot {} vs 2x on-demand {}",
            r.metrics.fpga_cost,
            2.0 * plain.metrics.fpga_cost
        );
        // Energy is price-independent.
        assert_eq!(r.metrics.total_energy(), plain.metrics.total_energy());
    }

    #[test]
    fn fault_free_attach_is_bit_identical() {
        // The fault-free pack (empty plan, no spot kinds) must leave every
        // metric bit-identical to a plain run — the zero-fault parity
        // contract the integration suite extends to the full roster.
        let trace = mini_trace(20, 0.5, 0.010);
        let plain = run(
            &trace,
            SimConfig::paper_default(),
            &defaults(),
            &mut OnePerRequest,
        );
        let scen = ScenarioConfig::fault_free();
        let plan = FaultPlan::build(&scen, 1, 0, 10.0);
        assert!(plan.faults.is_empty(), "fault-free pack must plan nothing");
        let (r, killed) = scenario_run(
            &trace,
            SimConfig::paper_default(),
            &scen,
            &plan,
            &mut OnePerRequest,
        );
        assert_eq!(killed, 0);
        assert_eq!(r.metrics.total_energy(), plain.metrics.total_energy());
        assert_eq!(r.metrics.total_cost(), plain.metrics.total_cost());
        assert_eq!(r.metrics.requests, plain.metrics.requests);
        assert_eq!(r.metrics.completions, plain.metrics.completions);
        assert_eq!(r.metrics.deadline_misses, plain.metrics.deadline_misses);
    }
}
