//! The simulation engine: event loop, worker lifecycle transitions, and the
//! mutation API schedulers use ([`SimState`]).

use super::event::{Event, EventQueue};
use super::metrics::{IdealBaseline, Metrics, RunResult};
use super::pool::Pool;
use super::worker::{Worker, WorkerId, WorkerState};
use super::{Request, Scheduler};
use crate::config::{PlatformConfig, SimConfig, WorkerKind};
use crate::trace::AppTrace;

/// Latency subsampling factor (1/N of completions recorded).
const LATENCY_SAMPLE: u64 = 61;

/// Simulation state handed to schedulers. All allocation, dispatch, and
/// retirement flows through this API so energy/cost accounting stays
/// consistent.
pub struct SimState {
    pub cfg: SimConfig,
    pub pool: Pool,
    pub metrics: Metrics,
    now: f64,
    events: EventQueue,
    /// Service-time sums dispatched this interval, per kind (Alg 1's
    /// 𝓕 and 𝓒 inputs). Reset by `take_interval_work`.
    interval_work_cpu: f64,
    interval_work_fpga: f64,
    completions_seen: u64,
    /// End of the arrival window (trace duration).
    trace_end: f64,
}

impl SimState {
    pub fn new(cfg: SimConfig) -> Self {
        Self {
            cfg,
            pool: Pool::new(),
            metrics: Metrics::default(),
            now: 0.0,
            events: EventQueue::new(),
            interval_work_cpu: 0.0,
            interval_work_fpga: 0.0,
            completions_seen: 0,
            trace_end: f64::INFINITY,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Whether the arrival window is still open (schedulers pinning fleets
    /// release them once the trace ends so the pool can drain).
    pub fn trace_live(&self) -> bool {
        self.now < self.trace_end
    }

    /// Service time of a `size`-CPU-seconds request on `kind`.
    pub fn service_time(&self, kind: WorkerKind, size: f64) -> f64 {
        self.cfg.platform.params(kind).service_time(size)
    }

    /// Number of allocated (spinning-up or active) workers of `kind`.
    pub fn allocated(&self, kind: WorkerKind) -> u32 {
        self.pool.allocated(kind)
    }

    /// Spin up a new worker. Returns `None` if the configured cap is
    /// reached. Alloc energy (busy power over the spin-up window) is
    /// accounted immediately.
    pub fn alloc(&mut self, kind: WorkerKind) -> Option<WorkerId> {
        let cap = match kind {
            WorkerKind::Cpu => self.cfg.max_cpus,
            WorkerKind::Fpga => self.cfg.max_fpgas,
        };
        let current = self.pool.allocated(kind);
        if let Some(cap) = cap {
            if current >= cap {
                return None;
            }
        }
        let params = *self.cfg.platform.params(kind);
        let now = self.now;
        let id = self
            .pool
            .insert(|id| Worker::new(id, kind, now, params.spin_up, current));
        self.events.push(now + params.spin_up, Event::SpinUpDone { worker: id });
        self.metrics.energy_mut(kind).alloc += params.spin_up_energy();
        // Peak tracks *allocated* workers (spinning-up + active), matching
        // the cap semantics; spinning-down workers are draining capacity.
        let allocated_now = current + 1;
        match kind {
            WorkerKind::Cpu => {
                self.metrics.cpu_spinups += 1;
                self.metrics.peak_cpus = self.metrics.peak_cpus.max(allocated_now);
            }
            WorkerKind::Fpga => {
                self.metrics.fpga_spinups += 1;
                self.metrics.peak_fpgas = self.metrics.peak_fpgas.max(allocated_now);
            }
        }
        Some(id)
    }

    /// Spin up `n` workers of `kind`; returns how many were granted.
    pub fn alloc_n(&mut self, kind: WorkerKind, n: u32) -> u32 {
        (0..n).take_while(|_| self.alloc(kind).is_some()).count() as u32
    }

    /// Allocate a worker that is already warm (statically provisioned
    /// before the workload window — FPGA-static's fleet). The one-time
    /// spin-up energy is still charged, but the worker is Active now.
    pub fn alloc_prewarmed(&mut self, kind: WorkerKind, n: u32) -> u32 {
        let granted = self.alloc_n(kind, n);
        let now = self.now;
        // Rewrite the just-created workers to be ready immediately and
        // cancel their pending SpinUpDone by making it a no-op (the event
        // handler tolerates already-active workers via state check below).
        let ids: Vec<_> = self
            .pool
            .iter_kind(kind)
            .filter(|w| w.state == WorkerState::SpinningUp && w.alloc_time == now)
            .map(|w| w.id)
            .collect();
        for id in ids {
            let w = self.pool.get_mut(id).unwrap();
            w.state = WorkerState::Active;
            w.ready_at = now;
            w.busy_until = now;
            w.idle_since = now;
            self.schedule_idle_timeout(id);
        }
        granted
    }

    /// Would `worker` finish a `size` request by `deadline` if dispatched
    /// now?
    pub fn can_finish(&self, worker: WorkerId, size: f64, deadline: f64) -> bool {
        let w = self.pool.get(worker).expect("can_finish: unknown worker");
        let svc = self.service_time(w.kind, size);
        w.accepting() && w.finish_time(self.now, svc) <= deadline
    }

    /// Dispatch a request to a specific worker; returns the completion
    /// time. Busy energy is attributed at dispatch (work conservation: all
    /// dispatched work runs to completion).
    pub fn dispatch(&mut self, req: Request, worker: WorkerId) -> f64 {
        let now = self.now;
        let w = self.pool.get_mut(worker).expect("dispatch: unknown worker");
        debug_assert!(w.accepting(), "dispatch to spinning-down worker");
        let kind = w.kind;
        let svc = self.cfg.platform.params(kind).service_time(req.size);
        let finish = w.assign(now, svc);
        self.events.push(
            finish,
            Event::Completion {
                worker,
                arrival: req.arrival,
                deadline: req.deadline,
            },
        );
        let params = self.cfg.platform.params(kind);
        self.metrics.energy_mut(kind).busy += svc * params.busy_power;
        self.metrics.requests += 1;
        self.metrics.total_work += req.size;
        match kind {
            WorkerKind::Cpu => {
                self.metrics.on_cpu += 1;
                self.interval_work_cpu += svc;
            }
            WorkerKind::Fpga => {
                self.metrics.on_fpga += 1;
                self.interval_work_fpga += svc;
            }
        }
        finish
    }

    /// Convenience used by every scheduler's burst path (Alg 3 line 6):
    /// spin up a CPU and queue the request on it. Falls back to the
    /// least-loaded live worker if the CPU cap is reached.
    pub fn dispatch_to_new_cpu(&mut self, req: Request) -> f64 {
        match self.alloc(WorkerKind::Cpu) {
            Some(id) => self.dispatch(req, id),
            None => {
                // Capped: best-effort onto the earliest-finishing worker.
                let best = self
                    .pool
                    .iter_all()
                    .filter(|w| w.accepting())
                    .min_by(|a, b| {
                        a.busy_until.partial_cmp(&b.busy_until).unwrap()
                    })
                    .map(|w| w.id)
                    .expect("no workers and CPU cap reached");
                self.dispatch(req, best)
            }
        }
    }

    /// Begin spin-down of an idle or never-used worker. Accounts idle
    /// energy accrued over its active window and the spin-down energy.
    pub fn retire(&mut self, worker: WorkerId) {
        let now = self.now;
        let w = self.pool.get_mut(worker).expect("retire: unknown worker");
        debug_assert!(
            w.state == WorkerState::Active && w.queued == 0,
            "retire requires an idle worker"
        );
        let kind = w.kind;
        let idle_secs = w.idle_seconds(now);
        w.state = WorkerState::SpinningDown;
        let params = self.cfg.platform.params(kind);
        self.metrics.energy_mut(kind).idle += idle_secs * params.idle_power;
        self.metrics.energy_mut(kind).dealloc += params.spin_down_energy();
        self.events
            .push(now + params.spin_down, Event::SpinDownDone { worker });
    }

    /// Retire up to `n` idle workers of `kind`, longest-idle first.
    /// Returns how many were retired.
    pub fn retire_idle(&mut self, kind: WorkerKind, n: u32) -> u32 {
        let now = self.now;
        let mut idle: Vec<(f64, WorkerId)> = self
            .pool
            .iter_kind(kind)
            .filter(|w| w.is_idle(now))
            .map(|w| (w.idle_since, w.id))
            .collect();
        idle.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        let take = idle.len().min(n as usize);
        for &(_, id) in idle.iter().take(take) {
            self.retire(id);
        }
        take as u32
    }

    /// Drain and reset the per-interval dispatched-work counters
    /// (CPU service-seconds, FPGA service-seconds).
    pub fn take_interval_work(&mut self) -> (f64, f64) {
        let out = (self.interval_work_cpu, self.interval_work_fpga);
        self.interval_work_cpu = 0.0;
        self.interval_work_fpga = 0.0;
        out
    }

    fn schedule_idle_timeout(&mut self, worker: WorkerId) {
        let w = self.pool.get(worker).expect("timeout: unknown worker");
        let timeout = match w.kind {
            WorkerKind::Cpu => self.cfg.cpu_idle_timeout,
            WorkerKind::Fpga => self.cfg.fpga_idle_timeout,
        };
        self.events.push(
            self.now + timeout,
            Event::IdleTimeout {
                worker,
                generation: w.generation,
            },
        );
    }
}

/// Run `sched` over `trace` under `cfg`; returns normalized results.
/// `defaults` parameterizes the idealized FPGA-only baseline (the paper
/// always normalizes against *default* Table 6 parameters).
pub fn run(
    trace: &AppTrace,
    cfg: SimConfig,
    defaults: &PlatformConfig,
    sched: &mut dyn Scheduler,
) -> RunResult {
    let mut sim = SimState::new(cfg);
    sim.trace_end = trace.duration;
    let deadline_factor = sim.cfg.deadline_factor;
    let interval = sched.interval();

    sched.on_start(&mut sim);

    let mut next_tick = if interval.is_finite() { interval } else { f64::INFINITY };
    let mut arrivals = trace.arrivals.iter().peekable();

    loop {
        let ta = arrivals.peek().map(|a| a.time).unwrap_or(f64::INFINITY);
        let te = sim.events.peek_time().unwrap_or(f64::INFINITY);
        // Ticks only while the trace is live; cleanup needs no allocator.
        let tt = if next_tick <= trace.duration { next_tick } else { f64::INFINITY };

        let t = ta.min(te).min(tt);
        if !t.is_finite() {
            break;
        }
        sim.now = t;

        if tt <= ta && tt <= te {
            next_tick += interval;
            sched.on_tick(&mut sim);
            continue;
        }
        if te <= ta {
            let (_, event) = sim.events.pop().unwrap();
            handle_event(&mut sim, sched, event);
            continue;
        }
        let a = arrivals.next().unwrap();
        let req = Request {
            arrival: a.time,
            size: a.size,
            deadline: a.time + deadline_factor * a.size,
        };
        sched.on_request(req, &mut sim);
    }

    debug_assert!(sim.pool.is_empty(), "pool not drained at end of run");
    RunResult {
        scheduler: sched.name(),
        ideal: IdealBaseline::for_work(sim.metrics.total_work, defaults),
        metrics: sim.metrics,
    }
}

fn handle_event(sim: &mut SimState, sched: &mut dyn Scheduler, event: Event) {
    match event {
        Event::SpinUpDone { worker } => {
            let Some(w) = sim.pool.get_mut(worker) else {
                return; // pre-warmed worker already retired
            };
            if w.state != WorkerState::SpinningUp {
                return; // pre-warmed via alloc_prewarmed; nothing to do
            }
            w.state = WorkerState::Active;
            if w.queued == 0 {
                w.idle_since = sim.now;
                sim.schedule_idle_timeout(worker);
            }
        }
        Event::Completion {
            worker,
            arrival,
            deadline,
        } => {
            let now = sim.now;
            if now > deadline + 1e-9 {
                sim.metrics.deadline_misses += 1;
            }
            sim.completions_seen += 1;
            if sim.completions_seen % LATENCY_SAMPLE == 0 {
                sim.metrics.latency.add(now - arrival);
            }
            let w = sim.pool.get_mut(worker).expect("completion: unknown worker");
            if w.complete_one(now) {
                sim.schedule_idle_timeout(worker);
            }
        }
        Event::IdleTimeout { worker, generation } => {
            let now = sim.now;
            let retire = match sim.pool.get(worker) {
                Some(w) => {
                    w.state == WorkerState::Active
                        && w.queued == 0
                        && w.generation == generation
                        && w.busy_until <= now
                }
                None => false,
            };
            if retire {
                if sched.keep_alive(worker, sim) {
                    // Pinned fleet / standing headroom: hold for another
                    // timeout period, then re-evaluate.
                    sim.schedule_idle_timeout(worker);
                } else {
                    sim.retire(worker);
                }
            }
        }
        Event::SpinDownDone { worker } => {
            let w = sim.pool.remove(worker);
            debug_assert_eq!(w.state, WorkerState::SpinningDown);
            let params = sim.cfg.platform.params(w.kind);
            let lifetime = sim.now - w.alloc_time;
            match w.kind {
                WorkerKind::Cpu => sim.metrics.cpu_cost += lifetime * params.cost_per_sec(),
                WorkerKind::Fpga => sim.metrics.fpga_cost += lifetime * params.cost_per_sec(),
            }
            sched.on_dealloc(w.kind, lifetime, w.peers_at_alloc, sim);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{AppTrace, Arrival};

    /// Trivial reactive scheduler: one new CPU per request (serverless
    /// 1:1). Exercises the full worker lifecycle.
    struct OnePerRequest;
    impl Scheduler for OnePerRequest {
        fn name(&self) -> String {
            "one-per-request".into()
        }
        fn interval(&self) -> f64 {
            f64::INFINITY
        }
        fn on_request(&mut self, req: Request, sim: &mut SimState) {
            sim.dispatch_to_new_cpu(req);
        }
    }

    /// Scheduler that packs everything onto a single pre-allocated FPGA.
    struct OneFpga {
        id: Option<WorkerId>,
    }
    impl Scheduler for OneFpga {
        fn name(&self) -> String {
            "one-fpga".into()
        }
        fn interval(&self) -> f64 {
            f64::INFINITY
        }
        fn on_start(&mut self, sim: &mut SimState) {
            self.id = Some(sim.alloc(WorkerKind::Fpga).unwrap());
        }
        fn on_request(&mut self, req: Request, sim: &mut SimState) {
            sim.dispatch(req, self.id.unwrap());
        }
    }

    fn mini_trace(n: usize, gap: f64, size: f64) -> AppTrace {
        let arrivals: Vec<Arrival> = (0..n)
            .map(|i| Arrival {
                time: i as f64 * gap,
                size,
            })
            .collect();
        let duration = n as f64 * gap;
        AppTrace::new("mini", arrivals, duration)
    }

    fn defaults() -> PlatformConfig {
        PlatformConfig::paper_default()
    }

    #[test]
    fn one_per_request_accounting() {
        let trace = mini_trace(10, 1.0, 0.010);
        let cfg = SimConfig::paper_default();
        let r = run(&trace, cfg.clone(), &defaults(), &mut OnePerRequest);
        let m = &r.metrics;
        assert_eq!(m.requests, 10);
        assert_eq!(m.on_cpu, 10);
        assert_eq!(m.cpu_spinups, 10);
        assert_eq!(m.deadline_misses, 0);
        // busy energy: 10 * 0.010s * 150W = 15 J
        assert!((m.cpu_energy.busy - 15.0).abs() < 1e-9);
        // alloc energy: 10 * 0.75 J
        assert!((m.cpu_energy.alloc - 7.5).abs() < 1e-9);
        // idle energy: each worker idles for the cpu idle timeout
        let expected_idle = 10.0 * cfg.cpu_idle_timeout * 30.0;
        assert!(
            (m.cpu_energy.idle - expected_idle).abs() < 1e-6,
            "idle {} vs {}",
            m.cpu_energy.idle,
            expected_idle
        );
        // cost: lifetime = spin_up + svc + timeout + spin_down each
        let life = 0.005 + 0.010 + cfg.cpu_idle_timeout + 0.005;
        assert!((m.cpu_cost - 10.0 * life * 0.668 / 3600.0).abs() < 1e-9);
        assert_eq!(m.fpga_spinups, 0);
    }

    #[test]
    fn single_fpga_packs_all() {
        // 10ms requests every 6ms on a 2x FPGA (5ms service): queue never
        // grows unboundedly; all served by one FPGA. Arrivals start after
        // the 10s spin-up so deadlines are reachable.
        let arrivals: Vec<Arrival> = (0..100)
            .map(|i| Arrival {
                time: 10.5 + i as f64 * 0.006,
                size: 0.010,
            })
            .collect();
        let trace = AppTrace::new("mini", arrivals, 11.2);
        let cfg = SimConfig::paper_default();
        let r = run(&trace, cfg, &defaults(), &mut OneFpga { id: None });
        let m = &r.metrics;
        assert_eq!(m.on_fpga, 100);
        assert_eq!(m.fpga_spinups, 1);
        assert_eq!(m.deadline_misses, 0);
        // busy energy = 100 * 0.005 * 50
        assert!((m.fpga_energy.busy - 25.0).abs() < 1e-9);
        assert!((m.fpga_energy.alloc - 500.0).abs() < 1e-9);
        assert_eq!(m.peak_fpgas, 1);
    }

    #[test]
    fn deadline_miss_detected() {
        // Single FPGA; burst of simultaneous arrivals with tight deadlines:
        // the tail of the queue must miss.
        let arrivals: Vec<Arrival> = (0..20)
            .map(|_| Arrival { time: 0.0, size: 0.010 })
            .collect();
        let trace = AppTrace::new("burst", arrivals, 1.0);
        let cfg = SimConfig::paper_default();
        let r = run(&trace, cfg, &defaults(), &mut OneFpga { id: None });
        // deadline = 0.1; spin_up 10s dominates → all miss.
        assert_eq!(r.metrics.deadline_misses, 20);
    }

    #[test]
    fn energy_conservation_identity() {
        // Total energy must equal the integral implied by component sums:
        // busy = total service x busy power, alloc = spinups x spin-up
        // energy, dealloc = spinups x spin-down energy (every worker dies).
        let trace = mini_trace(50, 0.3, 0.020);
        let cfg = SimConfig::paper_default();
        let r = run(&trace, cfg, &defaults(), &mut OnePerRequest);
        let m = &r.metrics;
        assert!((m.cpu_energy.busy - 50.0 * 0.020 * 150.0).abs() < 1e-9);
        assert!((m.cpu_energy.alloc - 50.0 * 0.75).abs() < 1e-9);
        assert!((m.cpu_energy.dealloc - 50.0 * 0.005 * 150.0).abs() < 1e-9);
        assert!((m.total_work - 50.0 * 0.020).abs() < 1e-9);
    }

    #[test]
    fn idle_timeout_respects_new_work() {
        // Requests arrive every 0.5 * timeout: worker should never retire
        // between them when timeout allows bridging.
        let mut cfg = SimConfig::paper_default();
        cfg.cpu_idle_timeout = 1.0;
        let trace = mini_trace(10, 0.5, 0.010);
        let r = run(&trace, cfg, &defaults(), &mut ReuseCpu { id: None });
        assert_eq!(r.metrics.cpu_spinups, 1, "worker should be reused");
    }

    /// Reuses one CPU if alive, else allocates.
    struct ReuseCpu {
        id: Option<WorkerId>,
    }
    impl Scheduler for ReuseCpu {
        fn name(&self) -> String {
            "reuse-cpu".into()
        }
        fn interval(&self) -> f64 {
            f64::INFINITY
        }
        fn on_request(&mut self, req: Request, sim: &mut SimState) {
            let alive = self
                .id
                .and_then(|id| sim.pool.get(id).map(|w| w.accepting()))
                .unwrap_or(false);
            if !alive {
                self.id = Some(sim.alloc(WorkerKind::Cpu).unwrap());
            }
            sim.dispatch(req, self.id.unwrap());
        }
    }

    #[test]
    fn caps_enforced() {
        let mut cfg = SimConfig::paper_default();
        cfg.max_cpus = Some(2);
        let trace = mini_trace(10, 0.0001, 0.010);
        let r = run(&trace, cfg, &defaults(), &mut OnePerRequest);
        assert!(r.metrics.peak_cpus <= 2);
        assert_eq!(r.metrics.requests, 10);
    }

    #[test]
    fn ticks_fire_while_trace_live() {
        struct TickCounter {
            ticks: u32,
        }
        impl Scheduler for TickCounter {
            fn name(&self) -> String {
                "ticks".into()
            }
            fn interval(&self) -> f64 {
                1.0
            }
            fn on_tick(&mut self, _sim: &mut SimState) {
                self.ticks += 1;
            }
            fn on_request(&mut self, req: Request, sim: &mut SimState) {
                sim.dispatch_to_new_cpu(req);
            }
        }
        let trace = mini_trace(5, 2.0, 0.010); // duration 10
        let mut s = TickCounter { ticks: 0 };
        run(&trace, SimConfig::paper_default(), &defaults(), &mut s);
        assert_eq!(s.ticks, 10); // t = 1..=10
    }
}
