//! Worker state machine.
//!
//! Lifecycle: `SpinningUp → Active (busy|idle) → SpinningDown → removed`.
//! Workers may be assigned work while spinning up (Alg 3's α list); their
//! effective start time is then their readiness time. Busy power is drawn
//! during spin up and spin down (§5.1).

use crate::config::WorkerKind;
use crate::policy::Request;
use std::collections::VecDeque;

// Worker identity and lifecycle are part of the transport-agnostic policy
// vocabulary; re-exported here so `sim::worker::{WorkerId, WorkerState}`
// paths keep working.
pub use crate::policy::{WorkerId, WorkerState};

#[derive(Clone, Debug)]
pub struct Worker {
    pub id: WorkerId,
    /// Never-reused identity stamped by the pool at insertion. Slab slots
    /// (and thus `id`) are recycled; events in flight across a scenario
    /// kill compare uids to detect staleness.
    pub uid: u64,
    pub kind: WorkerKind,
    pub state: WorkerState,
    /// When spin-up started (allocation instant).
    pub alloc_time: f64,
    /// When the worker is (or became) ready to process work.
    pub ready_at: f64,
    /// Completion horizon: all queued work finishes at this time.
    /// Invariant: `busy_until >= ready_at`.
    pub busy_until: f64,
    /// Number of queued + running requests.
    pub queued: u32,
    /// Cumulative seconds of service dispatched to this worker.
    pub busy_seconds: f64,
    /// Service seconds actually completed on this worker. The gap
    /// `busy_seconds - completed_seconds - remaining` is the executed-but-
    /// wasted work a scenario kill loses.
    pub completed_seconds: f64,
    /// Requests dispatched here and not yet completed, in completion
    /// (FIFO) order — service is serial, so completions pop the front.
    /// Each entry carries the dispatch's never-reused sequence number
    /// (hedge-pair linking). Drained and re-offered to the policy when the
    /// worker is killed.
    pub inflight: VecDeque<(Request, u64)>,
    /// Spot-billing basis: the scenario price integral C(t) at allocation
    /// (0 when no scenario is attached or the kind is not spot-billed).
    pub cost_basis: f64,
    /// Time the worker last became idle (valid when idle).
    pub idle_since: f64,
    /// Bumped on every dispatch; stale idle timeouts carry the old value.
    pub generation: u32,
    /// Number of same-kind workers allocated when this one was requested —
    /// the conditioning key for Spork's lifetime map 𝕃.
    pub peers_at_alloc: u32,
}

impl Worker {
    pub fn new(
        id: WorkerId,
        kind: WorkerKind,
        now: f64,
        spin_up: f64,
        peers_at_alloc: u32,
    ) -> Self {
        Self {
            id,
            // Stamped by the pool at insertion; 0 is a valid placeholder
            // for workers constructed outside a pool (unit tests).
            uid: 0,
            kind,
            state: WorkerState::SpinningUp,
            alloc_time: now,
            ready_at: now + spin_up,
            busy_until: now + spin_up,
            queued: 0,
            busy_seconds: 0.0,
            completed_seconds: 0.0,
            inflight: VecDeque::new(),
            cost_basis: 0.0,
            idle_since: now + spin_up,
            generation: 0,
            peers_at_alloc,
        }
    }

    /// Worker can accept new work (not spinning down).
    pub fn accepting(&self) -> bool {
        self.state != WorkerState::SpinningDown
    }

    /// Idle := active with an empty queue.
    pub fn is_idle(&self, now: f64) -> bool {
        self.state == WorkerState::Active && self.queued == 0 && self.busy_until <= now
    }

    pub fn is_busy(&self) -> bool {
        self.queued > 0
    }

    /// Completion time if a request needing `service` seconds were
    /// dispatched now.
    pub fn finish_time(&self, now: f64, service: f64) -> f64 {
        self.busy_until.max(now) + service
    }

    /// Outstanding queued work in seconds (the "load" used by packing
    /// policies).
    pub fn backlog(&self, now: f64) -> f64 {
        (self.busy_until - now.max(self.ready_at).min(self.busy_until)).max(0.0)
            + (self.busy_until - now).min(0.0).max(0.0) // 0; kept for clarity
    }

    /// Assign `service` seconds of work now; returns the completion time.
    pub fn assign(&mut self, now: f64, service: f64) -> f64 {
        debug_assert!(self.accepting());
        let finish = self.finish_time(now, service);
        self.busy_until = finish;
        self.queued += 1;
        self.busy_seconds += service;
        self.generation = self.generation.wrapping_add(1);
        finish
    }

    /// Mark one request complete; returns true if the worker is now idle.
    pub fn complete_one(&mut self, now: f64) -> bool {
        debug_assert!(self.queued > 0, "completion on empty worker");
        self.queued -= 1;
        if self.queued == 0 {
            self.idle_since = now;
            true
        } else {
            false
        }
    }

    /// Total time spent active (ready → `until`).
    pub fn active_seconds(&self, until: f64) -> f64 {
        (until - self.ready_at).max(0.0)
    }

    /// Idle seconds over the active window ending at `until`.
    pub fn idle_seconds(&self, until: f64) -> f64 {
        (self.active_seconds(until) - self.busy_seconds).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fresh() -> Worker {
        Worker::new(WorkerId(0), WorkerKind::Fpga, 100.0, 10.0, 3)
    }

    #[test]
    fn spin_up_window() {
        let w = fresh();
        assert_eq!(w.state, WorkerState::SpinningUp);
        assert_eq!(w.ready_at, 110.0);
        assert_eq!(w.busy_until, 110.0);
        assert_eq!(w.peers_at_alloc, 3);
        assert!(!w.is_idle(105.0));
    }

    #[test]
    fn assign_during_spin_up_starts_at_ready() {
        let mut w = fresh();
        let finish = w.assign(101.0, 2.0);
        assert_eq!(finish, 112.0); // ready 110 + 2
        assert_eq!(w.queued, 1);
    }

    #[test]
    fn fifo_queue_accumulates() {
        let mut w = fresh();
        w.state = WorkerState::Active;
        w.ready_at = 0.0;
        w.busy_until = 0.0;
        let f1 = w.assign(200.0, 1.0);
        let f2 = w.assign(200.0, 3.0);
        assert_eq!(f1, 201.0);
        assert_eq!(f2, 204.0);
        assert!(!w.complete_one(f1));
        assert!(w.complete_one(f2));
        assert_eq!(w.idle_since, f2);
        assert!(w.is_idle(f2));
    }

    #[test]
    fn idle_accounting() {
        let mut w = fresh(); // ready at 110
        w.state = WorkerState::Active;
        w.assign(110.0, 5.0); // busy 110-115
        w.complete_one(115.0);
        // active 110→120 = 10s, busy 5s → idle 5s
        assert!((w.idle_seconds(120.0) - 5.0).abs() < 1e-12);
        assert!((w.active_seconds(120.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn generation_bumps_on_assign() {
        let mut w = fresh();
        let g0 = w.generation;
        w.assign(100.0, 1.0);
        assert_ne!(w.generation, g0);
    }

    #[test]
    fn finish_time_idle_worker_starts_now() {
        let mut w = fresh();
        w.state = WorkerState::Active;
        w.ready_at = 0.0;
        w.busy_until = 50.0; // in the past relative to now=80
        assert_eq!(w.finish_time(80.0, 2.0), 82.0);
    }
}
