//! The transport-agnostic scheduling policy API — one policy, many
//! drivers.
//!
//! A [`Policy`] is a pure decision procedure: it receives typed
//! [`Observation`]s (ticks, arrivals, completions, worker lifecycle
//! events) together with a read-only [`PolicyView`] of the worker pool,
//! and returns typed [`Action`]s (allocate, dispatch, retire, keep-alive).
//! It never mutates driver state directly, so the same implementation runs
//! unchanged under
//!
//! * the **sim driver** ([`crate::sim::engine`]) — the discrete-event
//!   engine that evaluates policies at scale and keeps every accounting
//!   invariant (energy, cost, deadlines) in one place, and
//! * the **real-time driver** ([`crate::serve`]) — the serving runtime
//!   that paces the same decision loop against the wall clock and applies
//!   the actions to a warm pool of worker threads executing real compiled
//!   compute.
//!
//! Both drivers emit the applied-[`Effect`] stream, and
//! `rust/tests/policy_parity.rs` pins that the two streams are identical
//! for every scheduler in the Table 8 roster — served behavior equals
//! simulated behavior by construction.

mod types;
pub mod view;

pub use types::{Action, Effect, Observation, Request, Target, WorkerId, WorkerObs, WorkerState};
pub use view::{earliest_finishing, PolicyView};

/// A scheduling policy: the paper's Spork variants and every §5.1
/// baseline implement this.
pub trait Policy {
    /// Machine name (matches `SchedulerKind::name()` where applicable).
    fn name(&self) -> String;

    /// Scheduling interval T_s. Drivers tick at t = T_s, 2·T_s, ... while
    /// the trace is live. Return `f64::INFINITY` for purely reactive
    /// policies that don't want ticks.
    fn interval(&self) -> f64;

    /// Handle one observation, appending any resulting actions to `out`.
    /// Actions are applied by the driver in order, after this call
    /// returns; `view` always reflects the pre-action state.
    fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>);
}
