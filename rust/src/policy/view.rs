//! The read-only pool view drivers expose to policies.

use super::types::{WorkerId, WorkerObs};
use crate::config::WorkerKind;

/// What a policy may observe about the world between actions. Both drivers
/// implement this: the sim driver over [`crate::sim::SimState`]'s pool,
/// the real-time driver over the same state paced in wall-clock time.
///
/// Iteration order contract: `live_ids` / `for_each_worker` enumerate
/// workers in ascending id order (the pool's live index) — fully
/// deterministic and independent of removal history. Tie-breaking in
/// dispatch scans is therefore deterministic and driver-independent; a
/// new driver must reproduce this order (or share the pool) to keep
/// effect-stream parity.
pub trait PolicyView {
    /// Current time in trace seconds.
    fn now(&self) -> f64;

    /// Whether the arrival window is still open (schedulers pinning fleets
    /// release them once the trace ends so the pool can drain).
    fn trace_live(&self) -> bool;

    /// Service time of a `size`-CPU-seconds request on `kind`.
    fn service_time(&self, kind: WorkerKind, size: f64) -> f64;

    /// Number of allocated (spinning-up or active) workers of `kind`.
    fn allocated(&self, kind: WorkerKind) -> u32;

    /// Live worker ids of `kind` (any state), in allocation order.
    fn live_ids(&self, kind: WorkerKind) -> Vec<WorkerId>;

    /// Snapshot of one live worker.
    fn worker(&self, id: WorkerId) -> Option<WorkerObs>;

    /// Visit every live worker of `kind` in allocation order without
    /// materializing the id list (the dispatch hot path).
    fn for_each_worker(&self, kind: WorkerKind, f: &mut dyn FnMut(&WorkerObs)) {
        for id in self.live_ids(kind) {
            if let Some(w) = self.worker(id) {
                f(&w);
            }
        }
    }
}

/// Earliest-finishing accepting worker of `kind` — the best-effort
/// dispatch fallback of the FPGA-only baselines. First of equal minima
/// wins (matches `Iterator::min_by`).
pub fn earliest_finishing(view: &dyn PolicyView, kind: WorkerKind) -> Option<WorkerId> {
    let mut best: Option<(f64, WorkerId)> = None;
    view.for_each_worker(kind, &mut |w| {
        if w.accepting() && best.map_or(true, |(b, _)| w.busy_until < b) {
            best = Some((w.busy_until, w.id));
        }
    });
    best.map(|(_, id)| id)
}
