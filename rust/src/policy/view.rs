//! The read-only pool view drivers expose to policies.

use super::types::{WorkerId, WorkerObs, WorkerState};
use crate::config::WorkerKind;

/// What a policy may observe about the world between actions. Both drivers
/// implement this: the sim driver over [`crate::sim::SimState`]'s pool,
/// the real-time driver over the same state paced in wall-clock time.
///
/// Iteration order contract: `live_ids` / `for_each_worker` enumerate
/// workers in ascending id order (the pool's live index) — fully
/// deterministic and independent of removal history. Tie-breaking in
/// dispatch scans is therefore deterministic and driver-independent; a
/// new driver must reproduce this order (or share the pool) to keep
/// effect-stream parity.
///
/// # Indexed dispatch queries
///
/// The `*_feasible` / extremal methods answer the dispatch hot path's
/// preference classes (DESIGN.md §3, "indexed dispatch"). Each has a
/// reference scan as its default implementation, so a custom view only
/// has to implement the enumeration primitives; [`crate::sim::SimState`]
/// overrides them with O(log n) queries against the pool's ordered
/// indexes. The contract every override must honor (pinned by
/// `rust/tests/dispatch_parity.rs`): results are identical to the default
/// scan, including ties — equal-key extrema resolve to the lowest worker
/// id, and deadline feasibility is the *canonical comparison*
/// `busy_until.max(now) <= bound` with `bound = deadline - service_time`
/// (a prefix over `busy_until`, which is what makes the queries
/// indexable).
pub trait PolicyView {
    /// Current time in trace seconds.
    fn now(&self) -> f64;

    /// Whether the arrival window is still open (schedulers pinning fleets
    /// release them once the trace ends so the pool can drain).
    fn trace_live(&self) -> bool;

    /// Service time of a `size`-CPU-seconds request on `kind`.
    fn service_time(&self, kind: WorkerKind, size: f64) -> f64;

    /// Number of allocated (spinning-up or active) workers of `kind`.
    fn allocated(&self, kind: WorkerKind) -> u32;

    /// Live worker ids of `kind` (any state), in allocation order.
    fn live_ids(&self, kind: WorkerKind) -> Vec<WorkerId>;

    /// Snapshot of one live worker.
    fn worker(&self, id: WorkerId) -> Option<WorkerObs>;

    /// Visit every live worker of `kind` in allocation order without
    /// materializing the id list (the dispatch hot path).
    fn for_each_worker(&self, kind: WorkerKind, f: &mut dyn FnMut(&WorkerObs)) {
        for id in self.live_ids(kind) {
            if let Some(w) = self.worker(id) {
                f(&w);
            }
        }
    }

    /// Visit live ids of `kind` in ascending id order, starting strictly
    /// after `after` (from the smallest id when `None`). Stop early when
    /// `f` returns `false`. Overrides cursor the live index directly so
    /// round-robin dispatch allocates nothing per arrival.
    fn for_each_live_id_after(
        &self,
        kind: WorkerKind,
        after: Option<WorkerId>,
        f: &mut dyn FnMut(WorkerId) -> bool,
    ) {
        for id in self.live_ids(kind) {
            if let Some(a) = after {
                if id <= a {
                    continue;
                }
            }
            if !f(id) {
                return;
            }
        }
    }

    /// Busiest busy-Active worker of `kind` within the deadline prefix
    /// `busy_until <= bound` (Alg 3's β class): max `busy_until`, lowest
    /// id on ties. Returns `(busy_until, id)`. Busy workers always have
    /// `busy_until >= now`, so the prefix *is* the feasibility set.
    fn busiest_busy_feasible(&self, kind: WorkerKind, bound: f64) -> Option<(f64, WorkerId)> {
        let mut best: Option<(f64, WorkerId)> = None;
        self.for_each_worker(kind, &mut |w| {
            if w.state == WorkerState::Active
                && w.queued > 0
                && w.busy_until <= bound
                && best.map_or(true, |(b, _)| w.busy_until > b)
            {
                best = Some((w.busy_until, w.id));
            }
        });
        best
    }

    /// Most-recently-idle worker of `kind` (Alg 3's ι class): max
    /// `idle_since`, lowest id on ties. Returns `(idle_since, id)`. Idle
    /// workers satisfy `busy_until <= now`, so their deadline feasibility
    /// is uniform — the caller checks `now <= bound` once for the class.
    fn most_recently_idle(&self, kind: WorkerKind) -> Option<(f64, WorkerId)> {
        let mut best: Option<(f64, WorkerId)> = None;
        self.for_each_worker(kind, &mut |w| {
            if w.state == WorkerState::Active
                && w.queued == 0
                && best.map_or(true, |(s, _)| w.idle_since > s)
            {
                best = Some((w.idle_since, w.id));
            }
        });
        best
    }

    /// Most-loaded spinning-up worker of `kind` with `busy_until <= bound`
    /// (Alg 3's α class): max queued load (`busy_until - ready_at`),
    /// lowest feasible id on load ties. Returns `(queued_load, id)`.
    fn most_loaded_spinup_feasible(&self, kind: WorkerKind, bound: f64) -> Option<(f64, WorkerId)> {
        let mut best: Option<(f64, WorkerId)> = None;
        self.for_each_worker(kind, &mut |w| {
            if w.state == WorkerState::SpinningUp && w.busy_until <= bound {
                let load = w.busy_until - w.ready_at;
                if best.map_or(true, |(l, _)| load > l) {
                    best = Some((load, w.id));
                }
            }
        });
        best
    }

    /// Busiest feasible worker of `kind` over busy-Active *and*
    /// spinning-up workers (AutoScale index packing ranks both by
    /// completion horizon): max `busy_until <= bound`, lowest id on ties.
    /// Returns `(busy_until, id)`.
    fn busiest_packed_feasible(&self, kind: WorkerKind, bound: f64) -> Option<(f64, WorkerId)> {
        let mut best: Option<(f64, WorkerId)> = None;
        self.for_each_worker(kind, &mut |w| {
            let packed = w.state == WorkerState::SpinningUp
                || (w.state == WorkerState::Active && w.queued > 0);
            if packed
                && w.busy_until <= bound
                && best.map_or(true, |(b, _)| w.busy_until > b)
            {
                best = Some((w.busy_until, w.id));
            }
        });
        best
    }

    /// Earliest-finishing accepting worker of `kind`: min `busy_until`,
    /// lowest id on ties. Returns `(busy_until, id)` — the best-effort
    /// fallback of the FPGA-only baselines and capped dispatch.
    fn earliest_ready(&self, kind: WorkerKind) -> Option<(f64, WorkerId)> {
        let mut best: Option<(f64, WorkerId)> = None;
        self.for_each_worker(kind, &mut |w| {
            if w.accepting() && best.map_or(true, |(b, _)| w.busy_until < b) {
                best = Some((w.busy_until, w.id));
            }
        });
        best
    }

    /// Total in-flight (queued + running) requests across every live
    /// worker of every kind — the admission backlog a bounded-queue
    /// router sheds against. Reference scan by default; the sim view
    /// answers O(1) from a counter the pool maintains, so backpressure
    /// checks never reintroduce a per-arrival fleet scan.
    fn inflight_requests(&self) -> u64 {
        let mut total = 0u64;
        for kind in WorkerKind::ALL {
            self.for_each_worker(kind, &mut |w| total += w.queued as u64);
        }
        total
    }

    /// Current spot price of `kind` as a multiplier on its on-demand cost
    /// rate. 1.0 outside a scenario (and for non-spot kinds the multiplier
    /// is informational only — they bill at the on-demand rate).
    fn spot_price(&self, _kind: WorkerKind) -> f64 {
        1.0
    }

    /// Whether `kind` is spot-billed (and preemptible) under the attached
    /// scenario. Always `false` outside a scenario.
    fn is_spot(&self, _kind: WorkerKind) -> bool {
        false
    }
}

/// Earliest-finishing accepting worker of `kind` — the best-effort
/// dispatch fallback of the FPGA-only baselines. First of equal minima
/// wins (lowest id); an O(log n) probe of the pool's ready index under
/// the sim view, the reference scan for custom views.
pub fn earliest_finishing(view: &dyn PolicyView, kind: WorkerKind) -> Option<WorkerId> {
    view.earliest_ready(kind).map(|(_, id)| id)
}
