//! The shared vocabulary of the policy API: requests, worker identity and
//! lifecycle, the observations drivers feed to policies, and the actions
//! policies return.
//!
//! Everything here is `Copy` and transport-free: the same values describe a
//! simulated worker pool and the serving runtime's warm thread pool.

use crate::config::WorkerKind;

/// Stable worker identifier (slab index in the owning driver's pool).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WorkerId(pub u32);

/// Worker lifecycle: `SpinningUp → Active (busy|idle) → SpinningDown`.
/// Workers may be assigned work while spinning up (Alg 3's α list); their
/// effective start time is then their readiness time.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum WorkerState {
    SpinningUp,
    Active,
    SpinningDown,
}

/// One request moving through the system. Sizes are known in advance
/// (paper §4.5); `deadline` is absolute.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    pub arrival: f64,
    /// Service time on a CPU worker, seconds.
    pub size: f64,
    pub deadline: f64,
    /// Dispatch attempt: 0 for a fresh arrival, incremented each time the
    /// request is re-offered after its worker was preempted or failed.
    /// Policies may route retries differently (on-demand fallback).
    pub attempt: u32,
}

/// Read-only per-worker snapshot a policy sees through
/// [`super::PolicyView`].
#[derive(Clone, Copy, Debug)]
pub struct WorkerObs {
    pub id: WorkerId,
    pub kind: WorkerKind,
    pub state: WorkerState,
    /// When the worker is (or became) ready to process work.
    pub ready_at: f64,
    /// Completion horizon: all queued work finishes at this time.
    pub busy_until: f64,
    /// Number of queued + running requests.
    pub queued: u32,
    /// Time the worker last became idle (valid when idle).
    pub idle_since: f64,
}

impl WorkerObs {
    /// Worker can accept new work (not spinning down).
    pub fn accepting(&self) -> bool {
        self.state != WorkerState::SpinningDown
    }

    /// Completion time if a request needing `service` seconds were
    /// dispatched now.
    pub fn finish_time(&self, now: f64, service: f64) -> f64 {
        self.busy_until.max(now) + service
    }
}

/// What a driver tells a policy. Every variant is a point-in-time fact;
/// the current pool state is always available through the
/// [`super::PolicyView`] passed alongside.
#[derive(Clone, Copy, Debug)]
pub enum Observation {
    /// t = 0, before any arrivals (pre-provisioning hook).
    Start,
    /// The interval boundary at t = `index`·T_s. `cpu_work`/`fpga_work`
    /// are the service-time sums dispatched per kind during the interval
    /// that just ended (Alg 1's 𝓒 and 𝓕 inputs); the driver drains its
    /// counters before observing, so the sums arrive exactly once.
    Tick {
        index: usize,
        cpu_work: f64,
        fpga_work: f64,
    },
    /// A request arrived and must be dispatched by the returned actions
    /// (possibly to a fresh worker — Alg 3 line 6).
    Arrival { req: Request },
    /// A request finished on `worker`. `req` is the completed request as
    /// dispatched (hedge duplicates carry `attempt` one above the copy they
    /// shadow), so recovery layers can keep exact liveness maps without
    /// mirroring every per-worker FIFO.
    Completion { worker: WorkerId, req: Request },
    /// A worker finished spinning up and became available.
    WorkerReady { worker: WorkerId },
    /// `worker` sat idle for a full timeout window. Return
    /// [`Action::KeepAlive`] to hold it for another window (pinned fleets,
    /// standing headroom); return nothing to let the driver retire it.
    IdleExpired { worker: WorkerId },
    /// A worker fully deallocated (after spin-down). `lifetime` is
    /// alloc→dealloc; `peers_at_alloc` is the same-kind allocated count at
    /// the worker's allocation (Spork's 𝕃 key).
    Dealloc {
        kind: WorkerKind,
        lifetime: f64,
        peers_at_alloc: u32,
    },
    /// Scenario fault: `worker` was killed (spot preemption, or a hardware
    /// failure when `failure`). Its `lost` in-flight requests are re-offered
    /// to the policy as `Arrival` observations (attempt incremented) right
    /// after this observation, unless their retry budget or deadline is
    /// exhausted — then the driver records them as abandoned misses.
    Preempted {
        worker: WorkerId,
        kind: WorkerKind,
        failure: bool,
        lost: u32,
    },
    /// Scenario fault plan: the spot price of `kind` stepped to `price`
    /// (a multiplier on the kind's on-demand cost rate). Also readable any
    /// time via [`super::PolicyView::spot_price`].
    PriceTick { kind: WorkerKind, price: f64 },
    /// A deferred retry matured ([`Action::Defer`]): the request is back in
    /// the policy's hands and must now be dispatched or abandoned. Emitted
    /// only for requests a policy explicitly deferred — the fault-free
    /// path never sees it.
    RetryDue { req: Request },
    /// A policy-scheduled timer fired ([`Action::Timer`]). The driver
    /// attaches no meaning to `token`; recovery layers use it to anchor
    /// hedge checks and breaker probes. Never emitted unless requested.
    Timer { token: u64 },
    /// The driver dropped `req` for good (retry budget or deadline
    /// exhausted after a kill, or an explicit [`Action::Abandon`]): it was
    /// counted as an abandoned deadline miss and will produce no
    /// completion. Lets decorators retire their bookkeeping for it.
    Abandoned { req: Request },
}

/// Where a dispatch should land.
#[derive(Clone, Copy, Debug)]
pub enum Target {
    /// A specific live worker.
    Worker(WorkerId),
    /// Spin up a fresh worker of `kind` and queue the request on it — the
    /// burst path (Alg 3 line 6). If the worker cap is reached, the driver
    /// falls back to the earliest-finishing live worker.
    Fresh(WorkerKind),
}

/// What a policy asks a driver to do. Actions are applied in return order,
/// after the observation that produced them, so a policy's view is always
/// the pre-action state.
#[derive(Clone, Copy, Debug)]
pub enum Action {
    /// Spin up `n` workers of `kind`. `prewarmed` workers are ready
    /// immediately (statically provisioned before the workload window);
    /// the one-time spin-up energy is still charged.
    Alloc {
        kind: WorkerKind,
        n: u32,
        prewarmed: bool,
    },
    /// Dispatch a request.
    Dispatch { req: Request, to: Target },
    /// Begin spin-down of up to `n` idle workers of `kind`, longest-idle
    /// first.
    Retire { kind: WorkerKind, n: u32 },
    /// Hold the idle worker for another timeout window. Only meaningful in
    /// response to [`Observation::IdleExpired`].
    KeepAlive { worker: WorkerId },
    /// Dispatch a retried request (`req.attempt > 0`) after a preemption or
    /// failure. Applied exactly like [`Action::Dispatch`] — retries are
    /// never double-counted in the arrival metrics either way — but the
    /// explicit variant keeps the fallback policies' audit trail honest.
    Redispatch { req: Request, to: Target },
    /// Refuse admission: the request is dropped *now*, never dispatched
    /// (bounded-queue backpressure — an overloaded router answering fast
    /// beats one answering never). Counted in `Metrics::shed`, which
    /// extends arrival conservation to
    /// `requests == completions + abandoned + shed`. Only meaningful in
    /// response to [`Observation::Arrival`] for that same request.
    Shed { req: Request },
    /// Hold `req` until `until`, then hand it back as
    /// [`Observation::RetryDue`]. The backbone of capped-exponential-
    /// backoff retries: the request sits in the event heap (so the run
    /// cannot drain it away) and is not dispatched in the meantime.
    Defer { req: Request, until: f64 },
    /// Fire [`Observation::Timer`] with `token` at time `at`. Pure
    /// scheduling — no pool or metrics side effects.
    Timer { at: f64, token: u64 },
    /// Hedge a straggling dispatch: if `req` (matched bit-for-bit on
    /// arrival/size/deadline/attempt) is still in flight on some worker,
    /// dispatch a duplicate to `to`; first completion wins and books the
    /// request, the loser's completion only frees its worker (its energy
    /// stays billed — the duplicate really executed). No-op if the request
    /// already completed or is already hedged.
    Hedge { req: Request, to: Target },
    /// Give up on `req` now: counted as an abandoned deadline miss
    /// (`Metrics::abandoned`), keeping
    /// `requests == completions + abandoned + shed` exact. For retries
    /// whose remaining deadline can't cover `svc + backoff`.
    Abandon { req: Request },
    /// Record that a recovery layer quarantined `worker` (circuit breaker
    /// opened): counts `Metrics::quarantines` and emits
    /// [`Effect::Quarantined`]. Routing around the worker is the policy's
    /// job — the driver only makes the decision auditable.
    Quarantine { worker: WorkerId },
}

/// A resolved side effect a driver applied — the audit stream both drivers
/// emit, letting tests pin that the sim driver and the real-time driver
/// execute identical action sequences for the same policy and trace.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Effect {
    Allocated {
        worker: WorkerId,
        kind: WorkerKind,
        prewarmed: bool,
    },
    Dispatched {
        worker: WorkerId,
        kind: WorkerKind,
        arrival: f64,
        size: f64,
        deadline: f64,
        finish: f64,
    },
    Retired {
        worker: WorkerId,
        kind: WorkerKind,
    },
    KeptAlive {
        worker: WorkerId,
    },
    /// Scenario fault applied by the driver (not by a policy action): the
    /// worker was removed immediately, its in-flight work drained. Serving
    /// runtimes park the physical slot when they see this.
    Killed {
        worker: WorkerId,
        kind: WorkerKind,
        failure: bool,
    },
    /// A request was refused admission ([`Action::Shed`]): dropped without
    /// dispatch, counted in `Metrics::shed`. Serving runtimes send the
    /// client a load-shed rejection when they see this.
    Shed {
        arrival: f64,
        size: f64,
        deadline: f64,
        attempt: u32,
    },
    /// A request completed on `worker` (model clock): the winning copy of a
    /// hedged pair, or the sole copy of an unhedged dispatch. The losing
    /// copy of a settled hedge emits nothing — exactly one `Completed` per
    /// completed request, so completion-time accounting (latency under
    /// stubbed compute) can never double-book.
    Completed {
        worker: WorkerId,
        kind: WorkerKind,
        arrival: f64,
        finish: f64,
    },
    /// A recovery layer opened a circuit breaker on `worker`
    /// ([`Action::Quarantine`]). The worker stays in the pool; dispatches
    /// are routed around it until the breaker's cool-down probe succeeds.
    Quarantined {
        worker: WorkerId,
        kind: WorkerKind,
    },
}
