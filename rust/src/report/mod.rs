//! Result reporting: human-readable run summaries, JSON emission, and
//! writing experiment artifacts (markdown + CSV) under `results/`.

use crate::sim::RunResult;
use crate::trace::AppTrace;
use crate::util::json::Json;
use crate::util::table::{pct, ratio, Table};
use std::path::Path;

/// One-run plain-text report.
pub fn run_to_text(r: &RunResult, trace: &AppTrace) -> String {
    let m = &r.metrics;
    let mut out = String::new();
    out.push_str(&format!("scheduler        : {}\n", r.scheduler));
    out.push_str(&format!(
        "trace            : {} ({} requests, {:.0}s, {:.1} CPU-s of work)\n",
        trace.name,
        trace.len(),
        trace.duration,
        m.total_work
    ));
    out.push_str(&format!(
        "energy           : {:.1} J total (cpu {:.1} | fpga {:.1})\n",
        m.total_energy(),
        m.cpu_energy.total(),
        m.fpga_energy.total()
    ));
    out.push_str(&format!(
        "  fpga breakdown : alloc {:.1} busy {:.1} idle {:.1} dealloc {:.1}\n",
        m.fpga_energy.alloc, m.fpga_energy.busy, m.fpga_energy.idle, m.fpga_energy.dealloc
    ));
    out.push_str(&format!(
        "cost             : ${:.4} (cpu ${:.4} | fpga ${:.4})\n",
        m.total_cost(),
        m.cpu_cost,
        m.fpga_cost
    ));
    out.push_str(&format!(
        "energy efficiency: {} (vs idealized FPGA-only)\n",
        pct(r.energy_efficiency())
    ));
    out.push_str(&format!("relative cost    : {}\n", ratio(r.relative_cost())));
    out.push_str(&format!(
        "requests         : {} ({} on CPU, {} on FPGA)\n",
        m.requests, m.on_cpu, m.on_fpga
    ));
    out.push_str(&format!(
        "deadline misses  : {} ({})\n",
        m.deadline_misses,
        pct(r.miss_fraction())
    ));
    out.push_str(&format!(
        "spin-ups         : {} cpu, {} fpga | peak {} cpu, {} fpga\n",
        m.cpu_spinups, m.fpga_spinups, m.peak_cpus, m.peak_fpgas
    ));
    if m.shed > 0 {
        out.push_str(&format!(
            "shed             : {} refused admission (queue cap backpressure)\n",
            m.shed
        ));
    }
    if m.preemptions + m.worker_failures + m.redispatches + m.abandoned > 0 {
        out.push_str(&format!(
            "faults           : {} preempted, {} failed | {} re-dispatched, {} abandoned, {:.1}s work lost\n",
            m.preemptions, m.worker_failures, m.redispatches, m.abandoned, m.work_lost
        ));
    }
    out
}

/// One-run JSON report.
pub fn run_to_json(r: &RunResult) -> Json {
    let m = &r.metrics;
    let breakdown = |e: &crate::sim::EnergyBreakdown| {
        Json::obj(vec![
            ("alloc", Json::Num(e.alloc)),
            ("busy", Json::Num(e.busy)),
            ("idle", Json::Num(e.idle)),
            ("dealloc", Json::Num(e.dealloc)),
        ])
    };
    Json::obj(vec![
        ("scheduler", Json::Str(r.scheduler.clone())),
        ("energy_efficiency", Json::Num(r.energy_efficiency())),
        ("relative_cost", Json::Num(r.relative_cost())),
        ("energy_j", Json::Num(m.total_energy())),
        ("cost_usd", Json::Num(m.total_cost())),
        ("cpu_energy", breakdown(&m.cpu_energy)),
        ("fpga_energy", breakdown(&m.fpga_energy)),
        ("requests", Json::Num(m.requests as f64)),
        ("on_cpu", Json::Num(m.on_cpu as f64)),
        ("on_fpga", Json::Num(m.on_fpga as f64)),
        ("deadline_misses", Json::Num(m.deadline_misses as f64)),
        ("cpu_spinups", Json::Num(m.cpu_spinups as f64)),
        ("fpga_spinups", Json::Num(m.fpga_spinups as f64)),
        ("peak_cpus", Json::Num(m.peak_cpus as f64)),
        ("peak_fpgas", Json::Num(m.peak_fpgas as f64)),
        ("total_work", Json::Num(m.total_work)),
        ("preemptions", Json::Num(m.preemptions as f64)),
        ("worker_failures", Json::Num(m.worker_failures as f64)),
        ("redispatches", Json::Num(m.redispatches as f64)),
        ("abandoned", Json::Num(m.abandoned as f64)),
        ("work_lost", Json::Num(m.work_lost)),
        ("shed", Json::Num(m.shed as f64)),
    ])
}

/// Write a rendered table to `<dir>/<stem>.{txt,csv,md}`.
pub fn write_table(table: &Table, dir: &Path, stem: &str) -> anyhow::Result<()> {
    std::fs::create_dir_all(dir)?;
    std::fs::write(dir.join(format!("{stem}.txt")), table.render())?;
    std::fs::write(dir.join(format!("{stem}.csv")), table.to_csv())?;
    std::fs::write(dir.join(format!("{stem}.md")), table.to_markdown())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{IdealBaseline, Metrics};

    fn sample_run() -> RunResult {
        let mut m = Metrics::default();
        m.fpga_energy.busy = 100.0;
        m.fpga_cost = 0.01;
        m.requests = 10;
        m.on_fpga = 10;
        m.total_work = 4.0;
        RunResult {
            scheduler: "spork-e".into(),
            metrics: m,
            ideal: IdealBaseline {
                energy: 80.0,
                cost: 0.008,
            },
        }
    }

    #[test]
    fn text_contains_key_fields() {
        let trace = AppTrace::new("t", vec![], 10.0);
        let txt = run_to_text(&sample_run(), &trace);
        assert!(txt.contains("spork-e"));
        assert!(txt.contains("80.0%")); // efficiency
        assert!(txt.contains("1.25x")); // relative cost
    }

    #[test]
    fn json_parses_back() {
        let j = run_to_json(&sample_run());
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.f64_or("energy_efficiency", 0.0), 0.8);
        assert_eq!(parsed.str_or("scheduler", ""), "spork-e");
    }

    #[test]
    fn write_table_creates_three_files() {
        let dir = std::env::temp_dir().join(format!("spork-report-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut t = Table::new("T", &["a"]);
        t.row(vec!["1".into()]);
        write_table(&t, &dir, "demo").unwrap();
        for ext in ["txt", "csv", "md"] {
            assert!(dir.join(format!("demo.{ext}")).exists());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
