//! `spork` — CLI entrypoint: simulate traces, regenerate the paper's
//! tables and figures, generate workloads, and drive the serving runtime.

use spork::cli::{render_command_help, render_help, Args, Spec};
use spork::config::{
    PlatformConfig, SchedulerKind, SimConfig, SizeBucket,
};
use spork::sched;
use spork::trace::{self, production};
use spork::util::rng::Rng;
use spork::util::table::{pct, ratio, Table};

fn specs() -> Vec<Spec> {
    vec![
        Spec {
            name: "simulate",
            about: "run one scheduler over one synthetic trace and report metrics",
            opts: vec![
                ("scheduler", true, "cpu-dynamic|fpga-static|fpga-dynamic|mark-ideal|spork-{e,c,b}[-ideal]|greedy-spot|ondemand-fallback|spork-fallback (default spork-e)"),
                ("scenario", true, "fault pack: fault-free|mild|severe (default fault-free)"),
                ("burstiness", true, "b-model bias in [0.5,0.75] (default 0.6)"),
                ("rate", true, "mean request rate per second (default 1000)"),
                ("size", true, "request size in seconds (default 0.010)"),
                ("duration", true, "trace seconds (default 600)"),
                ("seed", true, "rng seed (default 1)"),
                ("fpga-spinup", true, "FPGA spin-up seconds (default 10)"),
                ("fpga-speedup", true, "FPGA speedup (default 2)"),
                ("fpga-busy-power", true, "FPGA busy watts (default 50)"),
                ("config", true, "JSON SimConfig file (overrides defaults)"),
                ("trace-file", true, "CSV arrival trace (overrides synthesis)"),
                ("json", false, "emit results as JSON"),
            ],
        },
        Spec {
            name: "compare",
            about: "run the full Table-8 scheduler roster on one trace",
            opts: vec![
                ("burstiness", true, "b-model bias (default 0.6)"),
                ("rate", true, "mean req/s (default 1000)"),
                ("size", true, "request size seconds (default 0.010)"),
                ("duration", true, "trace seconds (default 600)"),
                ("seed", true, "rng seed (default 1)"),
            ],
        },
        Spec {
            name: "trace-gen",
            about: "generate a workload (b-model or production-like) to a directory",
            opts: vec![
                ("out", true, "output directory (required)"),
                ("dataset", true, "azure|alibaba|bmodel (default bmodel)"),
                ("bucket", true, "short|medium|long (default short)"),
                ("burstiness", true, "b-model bias (default 0.6)"),
                ("rate", true, "mean req/s for bmodel (default 1000)"),
                ("size", true, "request size for bmodel (default 0.010)"),
                ("duration", true, "trace seconds (default 7200)"),
                ("scale", true, "production demand scale (default 1.0)"),
                ("max-apps", true, "cap on generated apps"),
                ("seed", true, "rng seed (default 1)"),
            ],
        },
        Spec {
            name: "experiment",
            about: "regenerate a paper table/figure: fig2 fig3 fig4 fig5 fig6 fig7 table8 table9 ablation scenario all",
            opts: vec![
                ("out", true, "results directory (default results/)"),
                ("seeds", true, "trace repetitions (default 10 synthetic, 1 production)"),
                ("scale", true, "demand scale for production traces (default 1.0)"),
                ("jobs", true, "parallel sweep workers (default 0 = all cores; 1 = serial)"),
                ("full", false, "paper-scale workloads (slow)"),
            ],
        },
        Spec {
            name: "bench-sim",
            about: "replay a large synthetic trace through the streaming sim path; writes BENCH_sim_throughput.json",
            opts: vec![
                ("arrivals", true, "target arrival count (default 1000000)"),
                ("rate", true, "mean req/s of the synthetic trace (default 2000)"),
                ("scheduler", true, "any Table-8 kind (default spork-e)"),
                ("seed", true, "rng stream seed (default 1)"),
                ("out", true, "output JSON path (default BENCH_sim_throughput.json)"),
                ("pool-sizes", true, "pool-scaling fleet sizes (default 100,1000,10000)"),
                ("scaling-arrivals", true, "arrivals per pool-scaling point (default 200000)"),
                ("assert-scaling", true, "max per-arrival cost ratio largest/smallest fleet"),
                ("fit", false, "also measure the §5.1 fitting searches (gallop+bisect, early abort)"),
                ("fit-arrivals", true, "arrivals for the fit axis workload (default 200000)"),
                ("fit-out", true, "fit axis output JSON (default BENCH_fit_passes.json)"),
                ("assert-fit-abort", true, "max trace fraction an aborted fitting pass may stream (e.g. 0.5)"),
                ("assert-fit-passes", true, "max full-trace-equivalent stream traversals per lockstep search (e.g. 2)"),
                ("jobs", true, "process-wide executor budget (default 0 = all cores; 1 = serial)"),
                ("par-apps", false, "also time a multi-app production cell at --jobs 1/2/0 (parity-checked)"),
                ("par-apps-count", true, "apps in the par-apps workload (default 8)"),
                ("par-apps-out", true, "par-apps axis output JSON (default BENCH_par_apps.json)"),
                ("assert-par-overhead", true, "max jobs=0 / jobs=1 wall ratio for the par-apps cell (e.g. 1.2)"),
                ("scenario", true, "also replay under a fault pack: fault-free|mild|severe"),
                ("scenario-arrivals", true, "arrivals for the scenario axis (default min(arrivals, 200000))"),
                ("scenario-out", true, "scenario axis output JSON (default BENCH_scenario.json)"),
            ],
        },
        Spec {
            name: "bench-serve",
            about: "replay a production-style workload through the sharded paced router; writes BENCH_serve.json",
            opts: vec![
                ("dataset", true, "azure|alibaba (default azure)"),
                ("bucket", true, "short|medium|long (default short)"),
                ("apps", true, "heavy-demand app count (default 8, capped at the dataset population)"),
                ("demand-scale", true, "production demand scale (default 0.05)"),
                ("duration", true, "simulated seconds per point (default 600)"),
                ("scales", true, "comma list of time-scale compressions (default 1,10,100)"),
                ("scheduler", true, "any Table-8 kind (default spork-e)"),
                ("shards", true, "router shards (default 4)"),
                ("queue-cap", true, "admission cap per app, 0 = unbounded (default 256)"),
                ("seed", true, "rng seed (default 1)"),
                ("out", true, "output JSON path (default BENCH_serve.json)"),
                ("assert-max-lag", true, "max wall-seconds of replay lag at any point (CI tripwire)"),
                ("assert-shed", true, "max shed fraction at any point; requires an armed --queue-cap (CI tripwire)"),
                ("chaos", true, "also replay a fault pack at the highest scale: fault-free|mild|severe"),
                ("chaos-out", true, "chaos axis output JSON (default BENCH_serve_chaos.json)"),
                ("assert-recovered", true, "min fraction of retried requests rescued on time; requires --chaos (CI tripwire)"),
                ("assert-no-hang", true, "max wall-seconds for the whole chaos run; requires --chaos (CI tripwire)"),
            ],
        },
        Spec {
            name: "serve",
            about: "serve a compiled model through the hybrid runtime (requires artifacts/, or --dry-run)",
            opts: vec![
                ("artifacts", true, "artifacts directory (default artifacts/)"),
                ("scheduler", true, "any Table-8 kind: cpu-dynamic|fpga-static|fpga-dynamic|mark-ideal|spork-{e,c,b}[-ideal] (default spork-e)"),
                ("rate", true, "offered simulated load req/s (default 40)"),
                ("duration", true, "wall seconds of load (default 20)"),
                ("burstiness", true, "b-model bias (default 0.65)"),
                ("time-scale", true, "simulated seconds per wall second (default 5)"),
                ("pool-cpus", true, "warm CPU pool size (default 0 = derive from trace demand)"),
                ("pool-fpgas", true, "warm FPGA pool size (default 0 = derive from trace demand)"),
                ("queue-cap", true, "shed arrivals past this many in-flight requests, 0 = unbounded (default 0)"),
                ("chaos", true, "replay a fault pack against the serving run: fault-free|mild|severe"),
                ("seed", true, "rng seed (default 1)"),
                ("dry-run", false, "stub compute: no artifacts, no pacing; model accounting only"),
            ],
        },
        Spec {
            name: "pareto",
            about: "sweep weighted energy/cost objectives (offline optimal, Fig 3)",
            opts: vec![
                ("burstiness", true, "b-model bias (default 0.65)"),
                ("rate", true, "mean req/s (default 10000)"),
                ("duration", true, "trace seconds (default 3600)"),
                ("points", true, "number of weights (default 9)"),
                ("seed", true, "rng seed (default 1)"),
            ],
        },
    ]
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let specs = specs();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
        print!("{}", render_help("spork", "hybrid FPGA-CPU scheduling (CS.DC 2023 reproduction)", &specs));
        return;
    }
    if argv.iter().any(|a| a == "--help") {
        if let Some(spec) = specs.iter().find(|s| s.name == argv[0]) {
            print!("{}", render_command_help("spork", spec));
            return;
        }
    }
    let args = match Args::parse(&argv, &specs) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n");
            eprint!("{}", render_help("spork", "hybrid FPGA-CPU scheduling", &specs));
            std::process::exit(2);
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("simulate") => cmd_simulate(&args),
        Some("compare") => cmd_compare(&args),
        Some("trace-gen") => cmd_trace_gen(&args),
        Some("experiment") => spork::exp::cmd_experiment(&args),
        Some("bench-sim") => spork::exp::cmd_bench_sim(&args),
        Some("bench-serve") => spork::exp::cmd_bench_serve(&args),
        Some("serve") => spork::serve::cmd_serve(&args),
        Some("pareto") => spork::opt::cmd_pareto(&args),
        _ => Err("no subcommand given; see --help".to_string()),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn build_cfg(args: &Args) -> Result<SimConfig, String> {
    let mut cfg = match args.get("config") {
        Some(path) => SimConfig::load(path).map_err(|e| e.to_string())?,
        None => SimConfig::paper_default(),
    };
    if let Some(v) = args.get("fpga-spinup") {
        let plat = PlatformConfig {
            fpga: spork::config::WorkerParams {
                spin_up: v.parse().map_err(|_| "bad --fpga-spinup")?,
                ..cfg.platform.fpga
            },
            ..cfg.platform
        };
        cfg = SimConfig::from_platform(plat);
    }
    cfg.platform.fpga.speedup = args.f64_or("fpga-speedup", cfg.platform.fpga.speedup)?;
    cfg.platform.fpga.busy_power = args.f64_or("fpga-busy-power", cfg.platform.fpga.busy_power)?;
    Ok(cfg)
}

fn synth_trace(args: &Args) -> Result<trace::AppTrace, String> {
    if let Some(path) = args.get("trace-file") {
        return trace::io::load_csv(std::path::Path::new(path)).map_err(|e| e.to_string());
    }
    let mut rng = Rng::new(args.u64_or("seed", 1)?);
    Ok(trace::synthetic_app(
        "cli",
        &mut rng,
        args.f64_or("burstiness", 0.6)?,
        args.f64_or("duration", 600.0)?,
        args.f64_or("rate", 1000.0)?,
        args.f64_or("size", 0.010)?,
    ))
}

fn cmd_simulate(args: &Args) -> Result<(), String> {
    let cfg = build_cfg(args)?;
    let name = args.str_or("scheduler", "spork-e");
    let kind = SchedulerKind::from_name(&name).ok_or(format!("unknown scheduler '{name}'"))?;
    let trace = synth_trace(args)?;
    let defaults = PlatformConfig::paper_default();
    let scen_name = args.str_or("scenario", "fault-free");
    let scen = spork::scenario::ScenarioConfig::from_name(&scen_name)
        .ok_or(format!("unknown scenario pack '{scen_name}' (fault-free|mild|severe)"))?;
    let r = if scen.is_adverse() {
        let seed = args.u64_or("seed", 1)?;
        sched::run_scheduler_scenario(
            &kind,
            &cfg,
            &defaults,
            &|| Box::new(trace.source()),
            &scen,
            seed,
            0,
        )
    } else {
        sched::run_scheduler(&kind, &trace, &cfg, &defaults)
    };
    if args.has_flag("json") {
        println!("{}", spork::report::run_to_json(&r));
    } else {
        print!("{}", spork::report::run_to_text(&r, &trace));
    }
    Ok(())
}

fn cmd_compare(args: &Args) -> Result<(), String> {
    let cfg = SimConfig::paper_default();
    let trace = synth_trace(args)?;
    let defaults = PlatformConfig::paper_default();
    let mut t = Table::new(
        &format!(
            "Scheduler comparison (b={}, rate={}, size={}s, {} requests)",
            args.str_or("burstiness", "0.6"),
            args.str_or("rate", "1000"),
            args.str_or("size", "0.010"),
            trace.len()
        ),
        &["Scheduler", "Energy Eff.", "Rel. Cost", "Miss %", "CPU req %", "FPGA spinups"],
    );
    for kind in SchedulerKind::table8_roster() {
        let r = sched::run_scheduler(&kind, &trace, &cfg, &defaults);
        t.row(vec![
            kind.display(),
            pct(r.energy_efficiency()),
            ratio(r.relative_cost()),
            pct(r.miss_fraction()),
            pct(r.metrics.cpu_request_fraction()),
            format!("{}", r.metrics.fpga_spinups),
        ]);
    }
    print!("{}", t.render());
    Ok(())
}

fn cmd_trace_gen(args: &Args) -> Result<(), String> {
    let out = args.get("out").ok_or("--out is required")?.to_string();
    let seed = args.u64_or("seed", 1)?;
    let mut rng = Rng::new(seed);
    let apps = match args.str_or("dataset", "bmodel").as_str() {
        "bmodel" => vec![trace::synthetic_app(
            "bmodel",
            &mut rng,
            args.f64_or("burstiness", 0.6)?,
            args.f64_or("duration", 7200.0)?,
            args.f64_or("rate", 1000.0)?,
            args.f64_or("size", 0.010)?,
        )],
        name => {
            let dataset = production::Dataset::from_name(name)
                .ok_or(format!("unknown dataset '{name}'"))?;
            let bucket = SizeBucket::from_name(&args.str_or("bucket", "short"))
                .ok_or("bad --bucket")?;
            let params = production::ProductionParams {
                dataset,
                bucket,
                duration: args.f64_or("duration", 7200.0)?,
                scale: args.f64_or("scale", 1.0)?,
                max_apps: args.get("max-apps").map(|v| v.parse().unwrap_or(usize::MAX)),
            };
            production::generate(&params, &mut rng)
        }
    };
    let total: usize = apps.iter().map(|a| a.len()).sum();
    trace::io::save_workload(&apps, std::path::Path::new(&out)).map_err(|e| e.to_string())?;
    println!("wrote {} apps ({} requests) to {}", apps.len(), total, out);
    Ok(())
}
