//! Domain scenario: how should an operator pick a platform for a bursty
//! interactive service? Sweeps burstiness for both the *offline optimal*
//! schedulers of §3 (what's achievable with perfect knowledge) and the
//! *online* schedulers of §4 (what Spork actually achieves), printing the
//! two side by side — a miniature of Fig 2 + Fig 5.
//!
//!     cargo run --release --example burst_tradeoffs

use spork::config::{PlatformConfig, SchedulerKind, SimConfig};
use spork::opt::{self, FluidInstance, PlatformMode};
use spork::sched::{self, Objective};
use spork::trace::{bmodel, synthetic_app, RateTrace};
use spork::util::rng::Rng;
use spork::util::table::{pct, ratio, Table};

fn main() {
    let platform = PlatformConfig::paper_default();
    let cfg = SimConfig::paper_default();

    let mut offline = Table::new(
        "Offline optimal (fluid model, energy objective) — Fig 2a miniature",
        &["burstiness", "CPU-only eff", "FPGA-only eff", "Hybrid eff", "Hybrid rel-cost"],
    );
    let mut online = Table::new(
        "Online schedulers (DES) — Fig 5 miniature",
        &["burstiness", "SporkE eff", "SporkE cost", "FPGA-static eff", "FPGA-static cost"],
    );

    for &b in &[0.5, 0.6, 0.7, 0.75] {
        // Offline: per-second b-model rates -> fluid instance -> DP.
        let mut rng = Rng::new(100 + (b * 100.0) as u64);
        let rates = RateTrace::new(1.0, bmodel::bmodel_rates(&mut rng, b, 1800, 2000.0));
        let inst = FluidInstance::from_rates(&rates, 0.010, platform.fpga.spin_up, platform);
        let cpu = opt::solve(&inst, PlatformMode::CpuOnly, Objective::energy());
        let fpga = opt::solve(&inst, PlatformMode::FpgaOnly, Objective::energy());
        let hybrid = opt::solve(&inst, PlatformMode::Hybrid, Objective::energy());
        offline.row(vec![
            format!("{b}"),
            pct(cpu.energy_efficiency(&inst)),
            pct(fpga.energy_efficiency(&inst)),
            pct(hybrid.energy_efficiency(&inst)),
            ratio(hybrid.relative_cost(&inst)),
        ]);

        // Online: per-minute synthetic trace -> full DES.
        let mut rng = Rng::new(200 + (b * 100.0) as u64);
        let trace = synthetic_app("bt", &mut rng, b, 1200.0, 500.0, 0.010);
        let spork = sched::run_scheduler(&SchedulerKind::spork_e(), &trace, &cfg, &platform);
        let stat = sched::run_scheduler(&SchedulerKind::FpgaStatic, &trace, &cfg, &platform);
        online.row(vec![
            format!("{b}"),
            pct(spork.energy_efficiency()),
            ratio(spork.relative_cost()),
            pct(stat.energy_efficiency()),
            ratio(stat.relative_cost()),
        ]);
    }
    print!("{}", offline.render());
    println!();
    print!("{}", online.render());
    println!("\nExpected shape: hybrid >= both homogeneous curves everywhere;");
    println!("Spork's margin over FPGA-static grows with burstiness.");
}
