//! Quickstart: simulate SporkE on a bursty synthetic workload and compare
//! it against the homogeneous baselines.
//!
//!     cargo run --release --example quickstart
//!
//! This is the 60-second tour of the library: generate a trace
//! (`spork::trace`), pick schedulers (`spork::config::SchedulerKind` +
//! `spork::sched`), run the discrete-event simulator (`spork::sim`), and
//! read the paper's two headline metrics off the results.

use spork::config::{PlatformConfig, SchedulerKind, SimConfig};
use spork::sched;
use spork::trace::synthetic_app;
use spork::util::rng::Rng;
use spork::util::table::{pct, ratio, Table};

fn main() {
    // A two-hour-class workload, scaled down to run in seconds: 20 minutes,
    // 500 req/s of 10 ms requests, moderately bursty (b = 0.65).
    let mut rng = Rng::new(7);
    let trace = synthetic_app("quickstart", &mut rng, 0.65, 1200.0, 500.0, 0.010);
    println!(
        "workload: {} requests, {:.0} CPU-seconds of demand over {:.0}s\n",
        trace.len(),
        trace.total_work(),
        trace.duration
    );

    // Paper-default platform (Table 6): 10s FPGA spin-up, 2x speedup,
    // 50 W vs 150 W busy power, $0.982 vs $0.668 per hour.
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();

    let mut table = Table::new(
        "SporkE vs homogeneous platforms (normalized to idealized FPGA-only)",
        &["Scheduler", "Energy Eff.", "Rel. Cost", "CPU req %", "Misses"],
    );
    for kind in [
        SchedulerKind::CpuDynamic,
        SchedulerKind::FpgaStatic,
        SchedulerKind::spork_e(),
    ] {
        let r = sched::run_scheduler(&kind, &trace, &cfg, &defaults);
        table.row(vec![
            kind.display(),
            pct(r.energy_efficiency()),
            ratio(r.relative_cost()),
            pct(r.metrics.cpu_request_fraction()),
            pct(r.miss_fraction()),
        ]);
    }
    print!("{}", table.render());
    println!("\nSporkE should beat CPU-dynamic ~5x on energy and FPGA-static on cost.");
}
