//! End-to-end driver (the DESIGN.md §4 "E2E serving" experiment): serve a
//! real compiled model through the full three-layer stack.
//!
//!     make artifacts && cargo run --release --example serve_hybrid
//!
//! What happens:
//! 1. `python/compile/aot.py` has lowered the MLP (L2 jax calling the L1
//!    Pallas kernel) to HLO text under `artifacts/` — built beforehand.
//! 2. A warm pool of worker threads compiles the artifacts via PJRT:
//!    "FPGA" workers get the Pallas build, CPU workers the jnp build.
//! 3. The router replays a bursty b-model trace in scaled real time: the
//!    real-time driver paces the shared policy core (SporkE here — any
//!    Table 8 kind works via `spork serve --scheduler`) and mirrors its
//!    alloc/dispatch/retire actions onto the warm pool; every request
//!    executes real XLA compute, batched dynamically.
//! 4. The report prints throughput, latency percentiles, deadline misses,
//!    the FPGA/CPU split, and Table 6 energy/cost — recorded in
//!    EXPERIMENTS.md.

use spork::serve::{run_serve, ServeConfig};
use spork::trace::synthetic_app_dt;
use spork::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::var("SPORK_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !std::path::Path::new(&artifacts).join("manifest.json").exists() {
        eprintln!("artifacts not found — run `make artifacts` first");
        std::process::exit(1);
    }

    // 5x time compression: a 10s FPGA "reconfiguration" takes 2 wall
    // seconds; 100 simulated seconds of bursty load run in 20 wall seconds.
    // Sized for small hosts (this image is single-core); raise the rate and
    // scale on bigger machines. Warm pool sizes derive from trace demand.
    let time_scale = 5.0;
    let cfg = ServeConfig::defaults(&artifacts, time_scale);
    let mut rng = Rng::new(42);
    let trace = synthetic_app_dt(
        "serve-hybrid",
        &mut rng,
        0.65,   // burstiness
        100.0,  // simulated seconds
        40.0,   // mean req/s (10 ms requests → ~0.2 FPGA-equivalents avg)
        0.010,  // request size
        30.0,   // rate slots
    );
    println!(
        "serving {} requests / {:.0} simulated s through the hybrid pool...",
        trace.len(),
        trace.duration
    );
    let mut report = run_serve(&cfg, &trace, &mut rng)?;
    print!("{}", report.render());

    // The run only counts if the system actually served: every request
    // completed, latencies are sane, and most work landed on the
    // energy-efficient workers after warm-up.
    assert_eq!(report.requests as usize, trace.len(), "dropped requests");
    assert!(
        report.latency_ms.percentile(50.0) < 100.0,
        "p50 blew past the deadline"
    );
    assert!(report.on_fpga > report.requests / 3, "FPGAs barely used");
    println!("\nserve_hybrid OK");
    Ok(())
}
