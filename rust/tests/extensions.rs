//! Tests for the paper's optional/extension features and CLI-level
//! behaviours: the §4.5 deadline-aware allocation flag, trace file
//! round-trips through the scheduler, and config-file loading.

use spork::config::{PlatformConfig, SchedulerKind, SimConfig};
use spork::sched;
use spork::trace::{io, synthetic_app};
use spork::util::rng::Rng;

#[test]
fn deadline_aware_extension_trades_efficiency_for_allocations() {
    // §4.5: deadline-aware FPGA allocation is future work in the paper;
    // our optional flag shaves allocations when queueing slack allows.
    // It must never break deadlines materially, and should not allocate
    // more FPGAs than the paper-faithful configuration.
    let mut rng = Rng::new(21);
    let trace = synthetic_app("ext", &mut rng, 0.6, 1200.0, 400.0, 0.010);
    let defaults = PlatformConfig::paper_default();

    let base_cfg = SimConfig::paper_default();
    let mut aware_cfg = SimConfig::paper_default();
    aware_cfg.deadline_aware = true;

    let base = sched::run_scheduler(&SchedulerKind::spork_e(), &trace, &base_cfg, &defaults);
    let aware = sched::run_scheduler(&SchedulerKind::spork_e(), &trace, &aware_cfg, &defaults);

    assert!(aware.miss_fraction() < 0.02, "misses {}", aware.miss_fraction());
    assert!(
        aware.metrics.fpga_spinups <= base.metrics.fpga_spinups,
        "deadline-aware should not allocate more ({} vs {})",
        aware.metrics.fpga_spinups,
        base.metrics.fpga_spinups
    );
}

#[test]
fn saved_trace_reproduces_simulation() {
    // trace → CSV → trace → identical simulation results.
    let mut rng = Rng::new(5);
    let trace = synthetic_app("rt", &mut rng, 0.65, 300.0, 150.0, 0.010);
    let dir = std::env::temp_dir().join(format!("spork-roundtrip-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("rt.csv");
    io::save_csv(&trace, &path).unwrap();
    let loaded = io::load_csv(&path).unwrap();

    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    let a = sched::run_scheduler(&SchedulerKind::spork_e(), &trace, &cfg, &defaults);
    let b = sched::run_scheduler(&SchedulerKind::spork_e(), &loaded, &cfg, &defaults);
    // CSV stores 6 decimal places; results must agree tightly.
    assert_eq!(a.metrics.requests, b.metrics.requests);
    assert!(
        (a.metrics.total_energy() - b.metrics.total_energy()).abs()
            < 1e-3 * a.metrics.total_energy()
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn config_file_drives_simulation() {
    // A 60s-spin-up config file must actually change behaviour.
    let dir = std::env::temp_dir().join(format!("spork-cfg-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("cfg.json");
    std::fs::write(
        &path,
        r#"{"platform": {"fpga": {"spin_up": 60.0}}}"#,
    )
    .unwrap();
    let cfg = SimConfig::load(path.to_str().unwrap()).unwrap();
    assert_eq!(cfg.platform.fpga.spin_up, 60.0);
    assert_eq!(cfg.interval, 60.0, "interval must follow A_f");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn all_experiment_ids_registered() {
    let ids: Vec<&str> = spork::exp::registry().iter().map(|(n, _, _)| *n).collect();
    for id in ["fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "table8", "table9"] {
        assert!(ids.contains(&id), "missing experiment {id}");
    }
}
