//! Dispatch-parity suite: the indexed dispatch hot path must pick the
//! exact worker the historical O(W) reference scan picks — for every
//! policy, pool shape, deadline regime, and tie pattern.
//!
//! Three layers:
//!
//! 1. **Pick parity** — randomized pools (quantized keys → dense ties) ×
//!    all three [`DispatchPolicy`] variants × tight/loose deadlines ×
//!    kind restrictions: `Dispatcher::find` (indexed queries under the
//!    sim view) equals an independent reference scan written against the
//!    enumeration primitives only.
//! 2. **Cursor parity** — round-robin pick *sequences* with pool churn
//!    between arrivals: the live-index cursor equals a materialized-list
//!    reference rotation.
//! 3. **Run parity** — full streaming runs dispatched via the indexed
//!    dispatcher vs the reference scans produce byte-identical effect
//!    streams and bit-identical aggregate metrics.
//!
//! Feasibility everywhere is the canonical comparison
//! `busy_until.max(now) <= bound`, `bound = deadline - service_time`
//! (see DESIGN.md § indexed dispatch).

use spork::config::{DispatchPolicy, PlatformConfig, SimConfig, WorkerKind};
use spork::policy::{
    Action, Effect, Observation, Policy, PolicyView, Request, Target, WorkerId, WorkerState,
};
use spork::sched::dispatch::Dispatcher;
use spork::sim::{self, SimState};
use spork::trace::synthetic_app;
use spork::util::prop::{prop_check, Case, PropResult};

// ---------------------------------------------------------------------
// Reference scans: the pre-index dispatch semantics, written only against
// the PolicyView enumeration primitives (never the indexed queries).
// ---------------------------------------------------------------------

fn feasible(w: &spork::policy::WorkerObs, now: f64, bound: f64) -> bool {
    w.accepting() && w.busy_until.max(now) <= bound
}

fn ref_efficient_first(
    view: &dyn PolicyView,
    req: &Request,
    kinds: &[WorkerKind],
) -> Option<WorkerId> {
    let now = view.now();
    for &kind in kinds {
        let bound = req.deadline - view.service_time(kind, req.size);
        let mut best_busy: Option<(f64, WorkerId)> = None;
        let mut best_idle: Option<(f64, WorkerId)> = None;
        let mut best_alloc: Option<(f64, WorkerId)> = None;
        view.for_each_worker(kind, &mut |w| {
            if !feasible(w, now, bound) {
                return;
            }
            match w.state {
                WorkerState::Active if w.queued > 0 => {
                    if best_busy.map_or(true, |(b, _)| w.busy_until > b) {
                        best_busy = Some((w.busy_until, w.id));
                    }
                }
                WorkerState::Active => {
                    if best_idle.map_or(true, |(s, _)| w.idle_since > s) {
                        best_idle = Some((w.idle_since, w.id));
                    }
                }
                WorkerState::SpinningUp => {
                    let load = w.busy_until - w.ready_at;
                    if best_alloc.map_or(true, |(l, _)| load > l) {
                        best_alloc = Some((load, w.id));
                    }
                }
                WorkerState::SpinningDown => {}
            }
        });
        if let Some((_, id)) = best_busy.or(best_idle).or(best_alloc) {
            return Some(id);
        }
    }
    None
}

fn ref_index_packing(
    view: &dyn PolicyView,
    req: &Request,
    kinds: &[WorkerKind],
) -> Option<WorkerId> {
    let now = view.now();
    let mut best_busy: Option<(f64, WorkerId)> = None;
    let mut best_idle: Option<(f64, WorkerId)> = None;
    for &kind in kinds {
        let bound = req.deadline - view.service_time(kind, req.size);
        view.for_each_worker(kind, &mut |w| {
            if !feasible(w, now, bound) {
                return;
            }
            if w.queued > 0 || w.state == WorkerState::SpinningUp {
                if best_busy.map_or(true, |(b, _)| w.busy_until > b) {
                    best_busy = Some((w.busy_until, w.id));
                }
            } else if best_idle.map_or(true, |(s, _)| w.idle_since > s) {
                best_idle = Some((w.idle_since, w.id));
            }
        });
    }
    best_busy.or(best_idle).map(|(_, id)| id)
}

/// Reference round robin: materialize the kind-major live list and rotate
/// a (kind, id) cursor over it — the allocation-heavy shape the indexed
/// cursor replaces.
#[derive(Default)]
struct RefRoundRobin {
    last: Option<(WorkerKind, WorkerId)>,
}

impl RefRoundRobin {
    fn find(
        &mut self,
        view: &dyn PolicyView,
        req: &Request,
        kinds: &[WorkerKind],
    ) -> Option<WorkerId> {
        let now = view.now();
        let ids: Vec<(WorkerKind, WorkerId)> = kinds
            .iter()
            .flat_map(|&k| view.live_ids(k).into_iter().map(move |id| (k, id)))
            .collect();
        if ids.is_empty() {
            return None;
        }
        let start = match self.last {
            None => 0,
            Some((lk, lid)) => match ids.iter().position(|&e| e == (lk, lid)) {
                Some(p) => p + 1,
                // Cursor worker gone: resume at the first entry past its
                // (kind position, id) rank; a cursor kind outside `kinds`
                // resets the rotation.
                None => match kinds.iter().position(|&x| x == lk) {
                    Some(lp) => ids
                        .iter()
                        .position(|&(k, id)| {
                            let kp = kinds.iter().position(|&x| x == k).unwrap();
                            (kp, id) > (lp, lid)
                        })
                        .unwrap_or(0),
                    None => 0,
                },
            },
        };
        for probe in 0..ids.len() {
            let (kind, id) = ids[(start + probe) % ids.len()];
            let bound = req.deadline - view.service_time(kind, req.size);
            let w = view.worker(id).unwrap();
            if feasible(&w, now, bound) {
                self.last = Some((kind, id));
                return Some(id);
            }
        }
        None
    }
}

// ---------------------------------------------------------------------
// Randomized pool scaffolding.
// ---------------------------------------------------------------------

/// Build a SimState at t = 0 whose workers are spread over every state
/// class, with *quantized* keys so equal-extremal ties are dense.
fn random_state(case: &mut Case) -> SimState {
    let mut sim = SimState::new(SimConfig::paper_default());
    let n = 1 + case.len(40);
    for _ in 0..n {
        let kind = if case.rng.chance(0.5) {
            WorkerKind::Fpga
        } else {
            WorkerKind::Cpu
        };
        let id = sim.alloc(kind).expect("uncapped alloc");
        let class = case.rng.below(10);
        let grid = 0.005 * case.rng.below(8) as f64;
        let queued = 1 + case.rng.below(3) as u32;
        let idle_grid = -0.005 * case.rng.below(8) as f64;
        let ready = 0.25 * (1 + case.rng.below(8)) as f64;
        let load = 0.005 * case.rng.below(4) as f64;
        sim.pool.with_mut(id, |w| match class {
            // Busy-Active: horizon on a small grid (>= now = 0).
            0..=3 => {
                w.state = WorkerState::Active;
                w.ready_at = 0.0;
                w.busy_until = grid;
                w.queued = queued;
            }
            // Idle-Active: busy_until <= now (sim invariant), idle_since
            // on a grid ending at 0 → heavy ties.
            4..=6 => {
                w.state = WorkerState::Active;
                w.ready_at = idle_grid;
                w.busy_until = 0.0;
                w.queued = 0;
                w.idle_since = idle_grid;
            }
            // Spinning up: ready in the future, quantized queued load.
            7..=8 => {
                w.ready_at = ready;
                w.busy_until = ready + load;
            }
            // Draining: never a candidate.
            _ => {
                w.state = WorkerState::SpinningDown;
            }
        });
    }
    sim
}

fn random_request(case: &mut Case) -> Request {
    let size = *case.rng.choose(&[0.005, 0.010, 0.020]);
    let factor = *case.rng.choose(&[1.0, 2.0, 5.0, 10.0, 1000.0]);
    Request {
        arrival: 0.0,
        size,
        deadline: size * factor,
        attempt: 0,
    }
}

fn random_kinds(case: &mut Case) -> &'static [WorkerKind] {
    *case.rng.choose(&[
        &[WorkerKind::Fpga, WorkerKind::Cpu][..],
        &[WorkerKind::Cpu, WorkerKind::Fpga][..],
        &[WorkerKind::Fpga][..],
        &[WorkerKind::Cpu][..],
    ])
}

// ---------------------------------------------------------------------
// 1. Pick parity.
// ---------------------------------------------------------------------

#[test]
fn indexed_picks_equal_reference_scan_picks() {
    prop_check(120, |case| {
        let sim = random_state(case);
        for _ in 0..6 {
            let req = random_request(case);
            let kinds = random_kinds(case);
            let eff = Dispatcher::new(DispatchPolicy::EfficientFirst).find(&sim, &req, kinds);
            let eff_ref = ref_efficient_first(&sim, &req, kinds);
            if eff != eff_ref {
                return PropResult::assert(
                    false,
                    format!(
                        "efficient-first: indexed {eff:?} != scan {eff_ref:?} for {req:?} \
                         kinds {kinds:?} (seed {})",
                        case.seed
                    ),
                );
            }
            let pack = Dispatcher::new(DispatchPolicy::IndexPacking).find(&sim, &req, kinds);
            let pack_ref = ref_index_packing(&sim, &req, kinds);
            if pack != pack_ref {
                return PropResult::assert(
                    false,
                    format!(
                        "index-packing: indexed {pack:?} != scan {pack_ref:?} for {req:?} \
                         kinds {kinds:?} (seed {})",
                        case.seed
                    ),
                );
            }
        }
        PropResult::pass()
    });
}

// ---------------------------------------------------------------------
// 2. Round-robin cursor parity under churn.
// ---------------------------------------------------------------------

#[test]
fn round_robin_sequences_equal_reference_rotation() {
    prop_check(80, |case| {
        let mut sim = random_state(case);
        let kinds = random_kinds(case);
        let mut indexed = Dispatcher::new(DispatchPolicy::RoundRobin);
        let mut reference = RefRoundRobin::default();
        for step in 0..10 {
            let req = random_request(case);
            let a = indexed.find(&sim, &req, kinds);
            let b = reference.find(&sim, &req, kinds);
            if a != b {
                return PropResult::assert(
                    false,
                    format!(
                        "round-robin step {step}: indexed {a:?} != reference {b:?} \
                         for {req:?} kinds {kinds:?} (seed {})",
                        case.seed
                    ),
                );
            }
            // Churn between arrivals: the rotation must stay aligned when
            // workers leave or flip class — including the cursor itself.
            if case.rng.chance(0.4) {
                let live: Vec<WorkerId> = WorkerKind::ALL
                    .iter()
                    .flat_map(|&k| sim.pool.live_ids(k))
                    .collect();
                if !live.is_empty() {
                    let victim = *case.rng.choose(&live);
                    if case.rng.chance(0.5) {
                        sim.pool.remove(victim);
                    } else {
                        let grid = 0.005 * case.rng.below(8) as f64;
                        sim.pool.with_mut(victim, |w| {
                            if w.state != WorkerState::SpinningUp {
                                w.state = WorkerState::Active;
                                w.queued = if grid > 0.0 { 1 } else { 0 };
                                w.ready_at = 0.0;
                                w.busy_until = grid;
                            }
                        });
                    }
                }
            }
        }
        PropResult::pass()
    });
}

// ---------------------------------------------------------------------
// 3. Full-run parity: byte-identical effect streams and metrics.
// ---------------------------------------------------------------------

/// A dispatch-only fleet policy parameterized by its finder, so the same
/// allocation/keep-alive behavior runs over the indexed and reference
/// dispatch paths.
struct FleetPolicy<'a> {
    fpgas: u32,
    cpus: u32,
    find: Box<dyn FnMut(&dyn PolicyView, &Request) -> Option<WorkerId> + 'a>,
}

const BOTH: &[WorkerKind] = &WorkerKind::EFFICIENT_FIRST;

impl Policy for FleetPolicy<'_> {
    fn name(&self) -> String {
        "fleet".into()
    }

    fn interval(&self) -> f64 {
        f64::INFINITY
    }

    fn observe(&mut self, obs: Observation, view: &dyn PolicyView, out: &mut Vec<Action>) {
        match obs {
            Observation::Start => {
                out.push(Action::Alloc {
                    kind: WorkerKind::Fpga,
                    n: self.fpgas,
                    prewarmed: true,
                });
                // Cold CPUs: arrivals inside their (5 ms) spin-up window
                // exercise the α preference class for real.
                out.push(Action::Alloc {
                    kind: WorkerKind::Cpu,
                    n: self.cpus,
                    prewarmed: false,
                });
            }
            Observation::Arrival { req } => {
                let to = match (self.find)(view, &req) {
                    Some(w) => Target::Worker(w),
                    None => Target::Fresh(WorkerKind::Cpu),
                };
                out.push(Action::Dispatch { req, to });
            }
            Observation::IdleExpired { worker } => {
                // Deterministic partial pinning: even ids stay while the
                // trace is live, odd ids drain — pool churn mid-run.
                if view.trace_live() && worker.0 % 2 == 0 {
                    out.push(Action::KeepAlive { worker });
                }
            }
            _ => {}
        }
    }
}

fn run_fleet(
    policy_kind: DispatchPolicy,
    indexed: bool,
    trace: &spork::trace::AppTrace,
    cfg: &SimConfig,
) -> (Vec<Effect>, spork::sim::RunResult) {
    let defaults = PlatformConfig::paper_default();
    let find: Box<dyn FnMut(&dyn PolicyView, &Request) -> Option<WorkerId>> = if indexed {
        let mut d = Dispatcher::new(policy_kind);
        Box::new(move |view, req| d.find(view, req, BOTH))
    } else {
        match policy_kind {
            DispatchPolicy::EfficientFirst => {
                Box::new(move |view, req| ref_efficient_first(view, req, BOTH))
            }
            DispatchPolicy::IndexPacking => {
                Box::new(move |view, req| ref_index_packing(view, req, BOTH))
            }
            DispatchPolicy::RoundRobin => {
                let mut rr = RefRoundRobin::default();
                Box::new(move |view, req| rr.find(view, req, BOTH))
            }
        }
    };
    let mut policy = FleetPolicy {
        fpgas: 3,
        cpus: 4,
        find,
    };
    let mut effects = Vec::new();
    let result = sim::run_with_sink(trace, cfg.clone(), &defaults, &mut policy, &mut |e| {
        effects.push(*e)
    });
    (effects, result)
}

#[test]
fn full_runs_are_byte_identical_across_dispatch_paths() {
    prop_check(6, |case| {
        let b = case.rng.range_f64(0.55, 0.75);
        let rate = case.rng.range_f64(60.0, 160.0);
        let mut rng = case.rng.fork(1);
        let trace = synthetic_app("parity", &mut rng, b, 90.0, rate, 0.010);
        let mut cfg = SimConfig::paper_default();
        // Tight-ish caps so the capped Fresh fallback fires too.
        cfg.max_cpus = Some(12);
        cfg.max_fpgas = Some(4);
        cfg.deadline_factor = *case.rng.choose(&[2.0, 10.0]);
        for policy_kind in [
            DispatchPolicy::EfficientFirst,
            DispatchPolicy::IndexPacking,
            DispatchPolicy::RoundRobin,
        ] {
            let (ea, ra) = run_fleet(policy_kind, true, &trace, &cfg);
            let (eb, rb) = run_fleet(policy_kind, false, &trace, &cfg);
            if ea != eb {
                let at = ea
                    .iter()
                    .zip(&eb)
                    .position(|(x, y)| x != y)
                    .unwrap_or(ea.len().min(eb.len()));
                return PropResult::assert(
                    false,
                    format!(
                        "{policy_kind:?}: effect streams diverge at index {at} \
                         ({} vs {} effects, seed {})",
                        ea.len(),
                        eb.len(),
                        case.seed
                    ),
                );
            }
            let same = ra.metrics.requests == rb.metrics.requests
                && ra.metrics.deadline_misses == rb.metrics.deadline_misses
                && ra.metrics.total_energy() == rb.metrics.total_energy()
                && ra.metrics.total_cost() == rb.metrics.total_cost();
            if !same {
                return PropResult::assert(
                    false,
                    format!("{policy_kind:?}: metrics diverge (seed {})", case.seed),
                );
            }
        }
        PropResult::pass()
    });
}
