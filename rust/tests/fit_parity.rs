//! Regression pins for the fitting-search rewrite and the sweep profile
//! cache:
//!
//! 1. **Search parity** — the production fit (lockstep engine) must
//!    equal the old linear-scan reference (same fitted fleet/headroom
//!    AND bit-identical winning run) across randomized tie-dense
//!    workloads, and the lockstep engine must equal the serial
//!    gallop+bisect engine on fitted candidate, winning run, overall
//!    feasibility, and per-candidate verdicts wherever the two probe the
//!    same candidate. Feasibility is monotone in the candidate (pinned
//!    separately by `more_headroom_fewer_misses`), so the least feasible
//!    candidate any of the three strategies finds is the same one.
//! 2. **Early-abort soundness** — a bounded pass aborts ⟺ the full pass
//!    would have been infeasible, and an unaborted bounded pass is
//!    bit-identical to the unbounded run.
//! 3. **Profile-cache parity** — `SweepGrid`'s shared-workload-profile
//!    output is bit-identical to per-cell recomputation (synthesize +
//!    `run_scheduler` per cell) for every `--jobs` value, and the
//!    production profile path matches the per-app path.

use spork::config::{PlatformConfig, SchedulerKind, SimConfig};
use spork::exp::{Cell, SweepCell, SweepGrid, WorkloadSpec};
use spork::sched::{self, fpga_dynamic, fpga_static, FitEngine, FitStats, FIT_HARD_CEILING};
use spork::sim::{self, Metrics, RunResult};
use spork::trace::{synthetic_app, AppTrace};
use spork::util::rng::Rng;

fn assert_runs_identical(a: &RunResult, b: &RunResult, what: &str) {
    let (ma, mb): (&Metrics, &Metrics) = (&a.metrics, &b.metrics);
    assert_eq!(ma.requests, mb.requests, "{what}: requests");
    assert_eq!(ma.deadline_misses, mb.deadline_misses, "{what}: misses");
    assert_eq!(ma.on_cpu, mb.on_cpu, "{what}: on_cpu");
    assert_eq!(ma.on_fpga, mb.on_fpga, "{what}: on_fpga");
    assert_eq!(ma.cpu_spinups, mb.cpu_spinups, "{what}: cpu_spinups");
    assert_eq!(ma.fpga_spinups, mb.fpga_spinups, "{what}: fpga_spinups");
    assert_eq!(ma.peak_cpus, mb.peak_cpus, "{what}: peak_cpus");
    assert_eq!(ma.peak_fpgas, mb.peak_fpgas, "{what}: peak_fpgas");
    assert_eq!(ma.total_work, mb.total_work, "{what}: total_work");
    assert_eq!(ma.total_energy(), mb.total_energy(), "{what}: energy");
    assert_eq!(ma.total_cost(), mb.total_cost(), "{what}: cost");
}

/// The pre-refactor linear scan for FPGA-dynamic, reimplemented from the
/// old `for k in 0.. { headroom = k * delta }` loop (uncapped: the old
/// cap of 8 silently returned an infeasible fit; every workload here
/// fits well below it anyway, asserted).
fn linear_fit_dynamic(trace: &AppTrace, cfg: &SimConfig, tol: f64) -> (RunResult, u32) {
    let oracle = sched::Oracle::from_trace(trace, cfg, sched::Objective::energy());
    let delta = oracle.max_consecutive_delta().max(1);
    for k in 0..=64u32 {
        let mut policy = fpga_dynamic::FpgaDynamic::new(cfg, k * delta);
        let r = sim::run(trace, cfg.clone(), &cfg.platform, &mut policy);
        if r.miss_fraction() <= tol {
            return (r, k);
        }
    }
    panic!("linear reference scan found no feasible headroom <= 64*delta");
}

/// The pre-refactor linear scan for FPGA-static (least fleet >= oracle
/// peak, sqrt-staffing step).
fn linear_fit_static(trace: &AppTrace, cfg: &SimConfig, tol: f64) -> (RunResult, u32) {
    let oracle = sched::Oracle::from_trace(trace, cfg, sched::Objective::energy());
    let peak = oracle.peak().max(1);
    let step = ((peak as f64).sqrt().ceil() as u32).max(1);
    for j in 0..=64u32 {
        let fleet = peak + j * step;
        let mut policy = fpga_static::FpgaStatic::with_fleet(fleet);
        let r = sim::run(trace, cfg.clone(), &cfg.platform, &mut policy);
        if r.miss_fraction() <= tol {
            return (r, fleet);
        }
    }
    panic!("linear reference scan found no feasible fleet <= peak + 64*step");
}

/// Randomized tie-dense workloads: short bursty traces where many
/// candidates sit near the feasibility boundary.
fn workloads() -> Vec<AppTrace> {
    let mut out = Vec::new();
    for (seed, b, rate, dur) in [
        (21u64, 0.55, 120.0, 180.0),
        (22, 0.65, 200.0, 240.0),
        (23, 0.70, 300.0, 180.0),
        (24, 0.60, 80.0, 300.0),
    ] {
        let mut rng = Rng::new(seed);
        out.push(synthetic_app("fp", &mut rng, b, dur, rate, 0.010));
    }
    out
}

#[test]
fn gallop_bisect_fit_equals_linear_scan_dynamic() {
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    for (i, trace) in workloads().iter().enumerate() {
        for tol in [0.005, 0.02] {
            let (lin_run, lin_k) = linear_fit_dynamic(trace, &cfg, tol);
            let (new_run, new_k) = fpga_dynamic::fit(trace, &cfg, &defaults, tol);
            assert_eq!(lin_k, new_k, "workload {i} tol {tol}: fitted k diverged");
            assert_runs_identical(&lin_run, &new_run, &format!("dynamic w{i} tol {tol}"));
        }
    }
}

#[test]
fn gallop_bisect_fit_equals_linear_scan_static() {
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    for (i, trace) in workloads().iter().enumerate() {
        for tol in [0.005, 0.02] {
            let (lin_run, lin_fleet) = linear_fit_static(trace, &cfg, tol);
            let (new_run, new_fleet) = fpga_static::fit(trace, &cfg, &defaults, tol);
            assert_eq!(
                lin_fleet, new_fleet,
                "workload {i} tol {tol}: fitted fleet diverged"
            );
            assert_runs_identical(&lin_run, &new_run, &format!("static w{i} tol {tol}"));
        }
    }
}

#[test]
fn early_abort_is_sound_for_every_candidate() {
    // A pass aborts ⟺ the full pass would have been infeasible — probed
    // across candidates straddling the feasibility boundary.
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    let mut rng = Rng::new(31);
    let trace = synthetic_app("ab", &mut rng, 0.7, 180.0, 250.0, 0.010);
    for tol in [0.0, 0.005, 0.05] {
        for headroom in [0u32, 2, 5, 10, 40] {
            let full = sim::run(
                &trace,
                cfg.clone(),
                &defaults,
                &mut fpga_dynamic::FpgaDynamic::new(&cfg, headroom),
            );
            let bounded = sim::run_source_bounded(
                Box::new(trace.source()),
                cfg.clone(),
                &defaults,
                &mut fpga_dynamic::FpgaDynamic::new(&cfg, headroom),
                tol,
            );
            let infeasible = full.miss_fraction() > tol;
            assert_eq!(
                bounded.aborted, infeasible,
                "headroom {headroom} tol {tol}: abort ⟺ infeasible violated \
                 (full miss fraction {})",
                full.miss_fraction()
            );
            if !bounded.aborted {
                assert_runs_identical(
                    &full,
                    &bounded.result,
                    &format!("headroom {headroom} tol {tol}"),
                );
            } else {
                assert!(
                    bounded.result.metrics.requests <= full.metrics.requests,
                    "aborted pass processed more than the full pass"
                );
            }
        }
    }
}

/// Per-candidate feasibility verdicts where two engines probed the same
/// candidate must agree (the serial engine bisects, the lockstep engine
/// sweeps the bracket, but the ladder rungs and the fitted candidate are
/// common ground).
fn assert_shared_verdicts_agree(a: &FitStats, b: &FitStats, what: &str) {
    for pa in a.passes() {
        for pb in b.passes() {
            // Skip the unbounded ceiling rerun: its pass is recorded with
            // the full-trace arrivals and a fresh feasibility evaluation,
            // but both engines only reach it already knowing the verdict.
            if pa.candidate == pb.candidate && pa.aborted == pb.aborted {
                assert_eq!(
                    pa.feasible, pb.feasible,
                    "{what}: engines disagree on candidate {}",
                    pa.candidate
                );
            }
        }
    }
}

#[test]
fn lockstep_fit_equals_serial_engine() {
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    for (i, trace) in workloads().iter().enumerate() {
        for tol in [0.005, 0.02] {
            let (sr, sk, ss) = fpga_dynamic::fit_source_stats_with(
                FitEngine::Serial,
                &|| Box::new(trace.source()),
                &cfg,
                &defaults,
                tol,
            );
            let (lr, lk, ls) = fpga_dynamic::fit_source_stats_with(
                FitEngine::Lockstep,
                &|| Box::new(trace.source()),
                &cfg,
                &defaults,
                tol,
            );
            assert_eq!(sk, lk, "dynamic w{i} tol {tol}: fitted k diverged");
            assert_eq!(ss.feasible, ls.feasible, "dynamic w{i} tol {tol}: feasible");
            assert_eq!(ss.fitted_candidate, ls.fitted_candidate);
            assert_eq!(ss.total_arrivals, ls.total_arrivals);
            assert_runs_identical(&sr, &lr, &format!("dynamic w{i} tol {tol} engines"));
            assert_shared_verdicts_agree(&ss, &ls, &format!("dynamic w{i} tol {tol}"));

            let (sr, sfleet, ss) = fpga_static::fit_source_stats_with(
                FitEngine::Serial,
                &|| Box::new(trace.source()),
                &cfg,
                &defaults,
                tol,
            );
            let (lr, lfleet, ls) = fpga_static::fit_source_stats_with(
                FitEngine::Lockstep,
                &|| Box::new(trace.source()),
                &cfg,
                &defaults,
                tol,
            );
            assert_eq!(sfleet, lfleet, "static w{i} tol {tol}: fitted fleet diverged");
            assert_eq!(ss.feasible, ls.feasible, "static w{i} tol {tol}: feasible");
            assert_eq!(ss.fitted_candidate, ls.fitted_candidate);
            assert_eq!(ss.total_arrivals, ls.total_arrivals);
            assert_runs_identical(&sr, &lr, &format!("static w{i} tol {tol} engines"));
            assert_shared_verdicts_agree(&ss, &ls, &format!("static w{i} tol {tol}"));
        }
    }
}

#[test]
fn infeasible_everywhere_reports_exact_total_arrivals() {
    // With deadline factor 0 every completion misses, so no candidate is
    // ever feasible: both engines must hit the hard ceiling, mark the
    // search infeasible, return a *full* run (not an aborted prefix),
    // and still report the workload's exact arrival count.
    let mut cfg = SimConfig::paper_default();
    cfg.deadline_factor = 0.0;
    let defaults = PlatformConfig::paper_default();
    let arrivals = vec![
        spork::trace::Arrival { time: 0.1, size: 0.010 },
        spork::trace::Arrival { time: 0.2, size: 0.010 },
        spork::trace::Arrival { time: 0.3, size: 0.010 },
    ];
    let trace = AppTrace::new("doomed", arrivals, 1.0);
    for engine in [FitEngine::Serial, FitEngine::Lockstep] {
        for (what, run, cand, stats) in [
            {
                let (r, k, s) = fpga_dynamic::fit_source_stats_with(
                    engine,
                    &|| Box::new(trace.source()),
                    &cfg,
                    &defaults,
                    0.005,
                );
                ("dynamic", r, k, s)
            },
            {
                let (r, fleet, s) = fpga_static::fit_source_stats_with(
                    engine,
                    &|| Box::new(trace.source()),
                    &cfg,
                    &defaults,
                    0.005,
                );
                ("static", r, fleet, s)
            },
        ] {
            assert!(!stats.feasible, "{what} {engine:?}: must be infeasible");
            assert_eq!(
                stats.fitted_candidate, FIT_HARD_CEILING,
                "{what} {engine:?}: ceiling candidate"
            );
            assert!(cand >= FIT_HARD_CEILING, "{what} {engine:?}: fitted value");
            assert_eq!(
                stats.total_arrivals, 3,
                "{what} {engine:?}: exact workload count even on the ceiling path"
            );
            assert_eq!(
                run.metrics.requests, 3,
                "{what} {engine:?}: returned run covers the whole trace"
            );
            assert_eq!(run.metrics.deadline_misses, 3);
            // The final recorded pass is the unbounded full rerun.
            let last = stats.passes().last().unwrap();
            assert!(!last.aborted);
            assert_eq!(last.arrivals, 3);
            assert_eq!(last.candidate, FIT_HARD_CEILING);
        }
    }
}

/// The old per-cell path: synthesize the trace for (cell, seed) and run
/// the scheduler on it directly — no shared profiles.
fn per_cell_reference(cells: &[SweepCell], seeds: u64) -> Vec<Cell> {
    let defaults = PlatformConfig::paper_default();
    let mut merged = vec![Cell::default(); cells.len()];
    for (c, cell) in cells.iter().enumerate() {
        for s in 0..seeds {
            let w = &cell.workload;
            let trace = AppTrace::from_source(&mut spork::trace::synthetic_source(
                "exp",
                Rng::for_stream(cell.seed_base, s),
                w.burstiness,
                w.duration,
                w.rate,
                w.size,
                60.0,
            ));
            let r = sched::run_scheduler(&cell.scheduler, &trace, &cell.cfg, &defaults);
            merged[c].add_run(&r.metrics, &r.ideal);
        }
    }
    merged.into_iter().map(Cell::finish).collect()
}

#[test]
fn sweep_profile_cache_matches_per_cell_recomputation() {
    // A roster heavy on profile consumers (two fitted kinds, two
    // oracle-assisted, two single-pass) over shared workloads: the cached
    // grid must be bit-identical to the uncached reference for every
    // --jobs value.
    let cfg = SimConfig::paper_default();
    let roster = [
        SchedulerKind::CpuDynamic,
        SchedulerKind::FpgaStatic,
        SchedulerKind::FpgaDynamic,
        SchedulerKind::MarkIdeal,
        SchedulerKind::spork_e(),
        SchedulerKind::spork_e_ideal(),
    ];
    let mut cells = Vec::new();
    for &b in &[0.55, 0.7] {
        for kind in &roster {
            cells.push(SweepCell {
                scheduler: kind.clone(),
                cfg: cfg.clone(),
                workload: WorkloadSpec {
                    burstiness: b,
                    rate: 100.0,
                    size: 0.010,
                    duration: 150.0,
                },
                seed_base: 77,
                scenario: None,
            });
        }
    }
    let seeds = 2;
    let reference = per_cell_reference(&cells, seeds);
    for jobs in [1usize, 2, 0] {
        let mut grid = SweepGrid::with(seeds, jobs);
        for cell in &cells {
            grid.push(cell.clone());
        }
        let got = grid.run();
        assert_eq!(
            got, reference,
            "profile-cached grid diverged from per-cell recomputation at jobs={jobs}"
        );
    }
}

#[test]
fn production_profile_path_matches_per_app_path() {
    use spork::config::SizeBucket;
    use spork::trace::production::{self, Dataset, ProductionParams};
    let cfg = SimConfig::paper_default();
    let params = ProductionParams {
        dataset: Dataset::AzureFunctions,
        bucket: SizeBucket::Short,
        duration: 600.0,
        scale: 0.2,
        max_apps: Some(3),
    };
    let apps = production::generate(&params, &mut Rng::new(11));
    for kind in [
        SchedulerKind::FpgaDynamic,
        SchedulerKind::MarkIdeal,
        SchedulerKind::spork_e(),
    ] {
        let direct = spork::exp::common::run_production(&kind, &cfg, &apps);
        let profiles = spork::exp::common::profile_apps(apps.clone(), &cfg);
        let cached = spork::exp::common::run_production_profiles(&kind, &cfg, &profiles);
        assert_eq!(direct, cached, "{} diverged on production apps", kind.name());
    }
}

#[test]
fn empty_workload_is_trivially_feasible() {
    // Zero-request runs must fit at the first candidate with miss
    // fraction 0.0 (not NaN) — the degenerate case the ratio-metric
    // guards exist for.
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    let trace = AppTrace::new("empty", Vec::new(), 30.0);
    let (r, k) = fpga_dynamic::fit(&trace, &cfg, &defaults, 0.005);
    assert_eq!(k, 0, "empty workload must fit at k=0");
    assert_eq!(r.miss_fraction(), 0.0);
    assert_eq!(r.metrics.requests, 0);
    let (r2, fleet) = fpga_static::fit(&trace, &cfg, &defaults, 0.005);
    assert_eq!(fleet, 1, "fleet is clamped to >= 1");
    assert_eq!(r2.miss_fraction(), 0.0);
}
