//! Integration: the AOT HLO-text → PJRT round trip, the correctness
//! contract between worker kinds, and the XLA-offloaded predictor vs the
//! rust predictor. Skipped gracefully (with a loud marker) when
//! `artifacts/` hasn't been built — run `make artifacts` first.

use spork::runtime::Runtime;

fn runtime() -> Option<Runtime> {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::load(dir).expect("runtime load"))
}

/// Deterministic pseudo-input (must not depend on rand crates).
fn test_input(len: usize, seed: u64) -> Vec<f32> {
    let mut rng = spork::util::rng::Rng::new(seed);
    (0..len).map(|_| rng.range_f64(-1.0, 1.0) as f32).collect()
}

#[test]
fn manifest_lists_expected_artifacts() {
    let Some(rt) = runtime() else { return };
    let names = rt.names();
    for base in ["app_fpga", "app_cpu"] {
        for batch in &rt.manifest.batch_sizes {
            assert!(
                names.contains(&format!("{base}_b{batch}")),
                "missing {base}_b{batch} in {names:?}"
            );
        }
    }
    assert!(names.contains(&"predictor".to_string()));
    assert_eq!(rt.manifest.layers, vec![128, 256, 128]);
}

#[test]
fn fpga_and_cpu_builds_agree_numerically() {
    // The hybrid-computing contract (§2.1): a request produces the same
    // answer on either worker kind. The FPGA build lowers through the
    // Pallas kernel, the CPU build through plain jnp — they must match.
    let Some(rt) = runtime() else { return };
    for &batch in &rt.manifest.batch_sizes.clone() {
        let fpga = rt.compile(&format!("app_fpga_b{batch}")).unwrap();
        let cpu = rt.compile(&format!("app_cpu_b{batch}")).unwrap();
        let x = test_input(fpga.arg_specs()[0].element_count(), 42 + batch as u64);
        let yf = fpga.run_f32(&[&x]).unwrap();
        let yc = cpu.run_f32(&[&x]).unwrap();
        assert_eq!(yf.len(), yc.len());
        assert_eq!(yf.len(), batch * 128);
        for (i, (a, b)) in yf.iter().zip(&yc).enumerate() {
            assert!(
                (a - b).abs() <= 1e-3 * (1.0 + a.abs().max(b.abs())),
                "batch {batch} output {i}: fpga {a} vs cpu {b}"
            );
        }
    }
}

#[test]
fn outputs_change_with_inputs() {
    let Some(rt) = runtime() else { return };
    let exe = rt.compile("app_fpga_b8").unwrap();
    let n = exe.arg_specs()[0].element_count();
    let y1 = exe.run_f32(&[&test_input(n, 1)]).unwrap();
    let y2 = exe.run_f32(&[&test_input(n, 2)]).unwrap();
    assert_ne!(y1, y2, "model must not be constant");
    // Repeatability.
    let y1b = exe.run_f32(&[&test_input(n, 1)]).unwrap();
    assert_eq!(y1, y1b);
}

#[test]
fn shape_mismatch_is_an_error() {
    let Some(rt) = runtime() else { return };
    let exe = rt.compile("app_fpga_b8").unwrap();
    let too_short = vec![0.0f32; 8];
    assert!(exe.run_f32(&[&too_short]).is_err());
    assert!(exe.run_f32(&[]).is_err());
}

#[test]
fn xla_predictor_matches_rust_predictor() {
    // The predictor artifact computes Alg 2's expectation; its argmin
    // must agree with the rust scalar implementation (spin-up
    // amortization disabled on both sides).
    use spork::config::PlatformConfig;
    use spork::sched::spork::predictor::Predictor;
    use spork::sched::Objective;

    let Some(rt) = runtime() else { return };
    let exe = rt.compile("predictor").unwrap();

    let cases: Vec<Vec<(u32, u32)>> = vec![
        vec![(5, 10)],                       // deterministic at 5
        vec![(2, 5), (10, 5)],               // bimodal
        vec![(1, 1), (3, 2), (8, 1), (20, 1)], // skewed
    ];
    for (ci, case) in cases.iter().enumerate() {
        for (obj, we, wc) in [
            (Objective::energy(), 1.0f32, 0.0f32),
            (Objective::cost(), 0.0, 1.0),
            (Objective::balanced(), 0.5, 0.5),
        ] {
            // Rust side.
            let mut p = Predictor::new(PlatformConfig::paper_default(), 10.0, obj);
            p.set_account_spinup(false);
            for &(value, count) in case {
                for _ in 0..count {
                    p.observe(7, value);
                }
            }
            let rust_pick = p.predict(7, 0);

            // XLA side: pad to the fixed kernel shapes.
            let total: u32 = case.iter().map(|&(_, c)| c).sum();
            let mut probs = vec![0.0f32; 64];
            let mut bins = vec![0.0f32; 64];
            for (i, &(value, count)) in case.iter().enumerate() {
                bins[i] = value as f32;
                probs[i] = count as f32 / total as f32;
            }
            let cands: Vec<f32> = (0..64).map(|i| i as f32).collect();
            let knobs = vec![
                10.0,
                50.0,
                20.0,
                150.0,
                2.0,
                0.982 / 3600.0,
                0.668 / 3600.0,
                we,
                wc,
            ];
            let scores = exe.run_f32(&[&probs, &bins, &cands, &knobs]).unwrap();
            // Argmin over the candidate range the rust side considers
            // (min..=max observed bins).
            let lo = case.iter().map(|&(v, _)| v).min().unwrap() as usize;
            let hi = case.iter().map(|&(v, _)| v).max().unwrap() as usize;
            let xla_pick = (lo..=hi)
                .min_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap())
                .unwrap() as u32;
            assert_eq!(
                rust_pick, xla_pick,
                "case {ci} ({we},{wc}): rust {rust_pick} vs xla {xla_pick} (scores {:?})",
                &scores[lo..=hi]
            );
        }
    }
}
