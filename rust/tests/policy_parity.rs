//! Driver-equivalence suite: the sim driver and the real-time serving
//! driver must execute *identical* action streams for the same policy,
//! trace, and pool caps — the acceptance bar of the policy-core redesign
//! (served behavior equals simulated behavior).
//!
//! The serve side runs at effectively infinite time scale with stubbed
//! compute (no artifacts, no worker threads, no pacing sleeps), so the
//! comparison is exact and fast. Every `SchedulerKind` in the Table 8
//! roster is replayed through both drivers.

use spork::config::{PlatformConfig, SchedulerKind};
use spork::policy::Effect;
use spork::sched;
use spork::serve::{run_serve_policy, Compute, ServeConfig};
use spork::sim;
use spork::trace::{synthetic_app, AppTrace};
use spork::util::rng::Rng;

const POOL_CPUS: usize = 8;
const POOL_FPGAS: usize = 4;

fn parity_trace() -> AppTrace {
    let mut rng = Rng::new(21);
    synthetic_app("parity", &mut rng, 0.6, 120.0, 60.0, 0.010)
}

fn serve_cfg() -> ServeConfig {
    // Stubbed compute never sleeps, so the time scale is nominal.
    let mut cfg = ServeConfig::defaults("unused-artifacts", 1e6);
    cfg.pool_cpus = POOL_CPUS;
    cfg.pool_fpgas = POOL_FPGAS;
    cfg
}

/// Action stream from the sim driver.
fn sim_effects(kind: &SchedulerKind, trace: &AppTrace) -> Vec<Effect> {
    let sim_cfg = serve_cfg().sim_config(POOL_CPUS, POOL_FPGAS);
    let mut policy = sched::build(kind, &sim_cfg, trace);
    let mut log = Vec::new();
    sim::run_with_sink(
        trace,
        sim_cfg,
        &PlatformConfig::paper_default(),
        policy.as_mut(),
        &mut |e| log.push(*e),
    );
    log
}

/// Action stream from the real-time driver (stubbed compute).
fn serve_effects(kind: &SchedulerKind, trace: &AppTrace) -> Vec<Effect> {
    let cfg = serve_cfg();
    let sim_cfg = cfg.sim_config(POOL_CPUS, POOL_FPGAS);
    let mut policy = sched::build(kind, &sim_cfg, trace);
    let mut rng = Rng::new(7);
    let mut log = Vec::new();
    run_serve_policy(
        &cfg,
        policy.as_mut(),
        trace,
        &mut rng,
        Compute::Stub,
        &mut |e| log.push(*e),
    )
    .expect("stub serve cannot fail");
    log
}

#[test]
fn every_table8_kind_runs_identically_under_both_drivers() {
    let trace = parity_trace();
    for kind in SchedulerKind::table8_roster() {
        let a = sim_effects(&kind, &trace);
        let b = serve_effects(&kind, &trace);
        assert!(
            !a.is_empty(),
            "{}: sim driver produced no effects",
            kind.name()
        );
        assert_eq!(
            a.len(),
            b.len(),
            "{}: effect counts diverge (sim {} vs serve {})",
            kind.name(),
            a.len(),
            b.len()
        );
        for (i, (ea, eb)) in a.iter().zip(&b).enumerate() {
            assert_eq!(
                ea,
                eb,
                "{}: drivers diverge at effect #{i}",
                kind.name()
            );
        }
    }
}

#[test]
fn spork_stream_is_pinned_and_complete() {
    let trace = parity_trace();
    let kind = SchedulerKind::spork_e();
    let stream = sim_effects(&kind, &trace);
    assert_eq!(stream, serve_effects(&kind, &trace));

    // Every request is dispatched exactly once, in arrival order.
    let dispatches: Vec<f64> = stream
        .iter()
        .filter_map(|e| match e {
            Effect::Dispatched { arrival, .. } => Some(*arrival),
            _ => None,
        })
        .collect();
    assert_eq!(dispatches.len(), trace.len());
    for (d, a) in dispatches.iter().zip(&trace.arrivals) {
        assert_eq!(*d, a.time);
    }

    // The stream exercises the full action vocabulary: allocations and
    // retirements must balance (pool drained at end of run).
    let allocs = stream
        .iter()
        .filter(|e| matches!(e, Effect::Allocated { .. }))
        .count();
    let retires = stream
        .iter()
        .filter(|e| matches!(e, Effect::Retired { .. }))
        .count();
    assert!(allocs > 0, "Spork never allocated");
    assert_eq!(allocs, retires, "every allocated worker must retire");
}

#[test]
fn parity_holds_under_tight_pool_caps() {
    // Caps force the Fresh-dispatch fallback (cap reached → earliest-
    // finishing worker) onto both drivers; they must still agree.
    let mut rng = Rng::new(33);
    let trace = synthetic_app("tight", &mut rng, 0.7, 90.0, 120.0, 0.010);
    let mut cfg = ServeConfig::defaults("unused-artifacts", 1e6);
    cfg.pool_cpus = 2;
    cfg.pool_fpgas = 1;
    let sim_cfg = cfg.sim_config(2, 1);
    for kind in [
        SchedulerKind::spork_e(),
        SchedulerKind::CpuDynamic,
        SchedulerKind::MarkIdeal,
    ] {
        let mut p1 = sched::build(&kind, &sim_cfg, &trace);
        let mut a = Vec::new();
        sim::run_with_sink(
            &trace,
            sim_cfg.clone(),
            &PlatformConfig::paper_default(),
            p1.as_mut(),
            &mut |e| a.push(*e),
        );
        let mut p2 = sched::build(&kind, &sim_cfg, &trace);
        let mut b = Vec::new();
        let mut rng2 = Rng::new(1);
        run_serve_policy(&cfg, p2.as_mut(), &trace, &mut rng2, Compute::Stub, &mut |e| {
            b.push(*e)
        })
        .unwrap();
        assert_eq!(a, b, "{} diverged under caps", kind.name());
    }
}
