//! Regression: the parallel sweep engine must be bit-deterministic in
//! the worker count — the same grid produces *identical* `Cell`s for
//! `--jobs 1` and `--jobs N`, for Spork and MArk-ideal (the two
//! predictive schedulers with the most internal state), and the rendered
//! report tables must match byte-for-byte.

use spork::config::{SchedulerKind, SimConfig};
use spork::exp::{Cell, SweepCell, SweepGrid, WorkloadSpec};
use spork::util::table::{pct, ratio, Table};

fn sensitivity_grid(jobs: usize) -> Vec<Cell> {
    let mut grid = SweepGrid::with(2, jobs);
    for &b in &[0.55, 0.7] {
        for kind in [SchedulerKind::spork_e(), SchedulerKind::MarkIdeal] {
            grid.push(SweepCell {
                scheduler: kind,
                cfg: SimConfig::paper_default(),
                workload: WorkloadSpec {
                    burstiness: b,
                    rate: 120.0,
                    size: 0.010,
                    duration: 180.0,
                },
                seed_base: 31,
                scenario: None,
            });
        }
    }
    grid.run()
}

fn render(cells: &[Cell]) -> String {
    let mut t = Table::new(
        "determinism check",
        &["Energy Eff.", "Rel. Cost", "Miss %", "spinups"],
    );
    for c in cells {
        t.row(vec![
            pct(c.energy_eff),
            ratio(c.rel_cost),
            pct(c.miss_frac),
            format!("{}", c.fpga_spinups),
        ]);
    }
    format!("{}\n{}\n{}", t.render(), t.to_csv(), t.to_markdown())
}

#[test]
fn jobs_count_does_not_change_results() {
    let serial = sensitivity_grid(1);
    for jobs in [2, 4, 0] {
        let parallel = sensitivity_grid(jobs);
        // Exact equality, field by field — not approximate: the engine
        // promises bit-identical floats for any worker count.
        assert_eq!(serial, parallel, "jobs={jobs} diverged from serial");
    }
}

#[test]
fn rendered_reports_are_byte_identical_across_jobs() {
    let a = render(&sensitivity_grid(1));
    let b = render(&sensitivity_grid(4));
    assert_eq!(a, b, "report output must be byte-identical");
}

#[test]
fn repeated_runs_are_stable() {
    // Same grid, same jobs, run twice: guards against any hidden global
    // state (statics, thread-local RNGs) sneaking into the sweep path.
    assert_eq!(sensitivity_grid(3), sensitivity_grid(3));
}
