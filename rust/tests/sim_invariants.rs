//! Property-based integration tests over the simulator + schedulers,
//! using the in-repo prop harness (`spork::util::prop`).

use spork::config::{PlatformConfig, SchedulerKind, SimConfig};
use spork::sched;
use spork::trace::{synthetic_app, AppTrace, Arrival};
use spork::util::prop::{prop_check, PropResult};
use spork::util::rng::Rng;

fn defaults() -> PlatformConfig {
    PlatformConfig::paper_default()
}

#[test]
fn every_scheduler_conserves_requests() {
    // No request is ever dropped or double-served, for any scheduler, on
    // randomized bursty workloads.
    prop_check(12, |case| {
        let b = case.rng.range_f64(0.5, 0.75);
        let rate = case.rng.range_f64(50.0, 400.0);
        let trace = synthetic_app(
            "prop",
            &mut case.rng,
            b,
            240.0,
            rate,
            0.010,
        );
        let cfg = SimConfig::paper_default();
        for kind in SchedulerKind::table8_roster() {
            let r = sched::run_scheduler(&kind, &trace, &cfg, &defaults());
            let p = PropResult::assert(
                r.metrics.requests as usize == trace.len()
                    && r.metrics.on_cpu + r.metrics.on_fpga == r.metrics.requests,
                format!(
                    "{}: {} requests in, {} dispatched (seed {})",
                    kind.name(),
                    trace.len(),
                    r.metrics.requests,
                    case.seed
                ),
            );
            if !p.ok {
                return p;
            }
        }
        PropResult::pass()
    });
}

#[test]
fn busy_energy_identity() {
    // Busy energy must equal total dispatched service time x busy power,
    // exactly, per worker kind (work conservation in the accounting).
    prop_check(10, |case| {
        let b = case.rng.range_f64(0.5, 0.75);
        let trace = synthetic_app("prop", &mut case.rng, b, 300.0, 200.0, 0.010);
        let cfg = SimConfig::paper_default();
        let r = sched::run_scheduler(&SchedulerKind::spork_e(), &trace, &cfg, &defaults());
        let m = &r.metrics;
        // on_fpga requests ran at size/2 on 50 W; on_cpu at size on 150 W.
        let expect_fpga = m.on_fpga as f64 * 0.010 / 2.0 * 50.0;
        let expect_cpu = m.on_cpu as f64 * 0.010 * 150.0;
        PropResult::approx_eq(m.fpga_energy.busy, expect_fpga, 1e-9, "fpga busy")
            .and(PropResult::approx_eq(m.cpu_energy.busy, expect_cpu, 1e-9, "cpu busy"))
    });
}

#[test]
fn energy_components_nonnegative_and_cost_positive() {
    prop_check(10, |case| {
        let b = case.rng.range_f64(0.5, 0.75);
        let trace = synthetic_app("prop", &mut case.rng, b, 200.0, 150.0, 0.020);
        let cfg = SimConfig::paper_default();
        for kind in [
            SchedulerKind::spork_c(),
            SchedulerKind::MarkIdeal,
            SchedulerKind::CpuDynamic,
        ] {
            let r = sched::run_scheduler(&kind, &trace, &cfg, &defaults());
            let m = &r.metrics;
            for (label, v) in [
                ("cpu alloc", m.cpu_energy.alloc),
                ("cpu busy", m.cpu_energy.busy),
                ("cpu idle", m.cpu_energy.idle),
                ("fpga idle", m.fpga_energy.idle),
                ("fpga dealloc", m.fpga_energy.dealloc),
            ] {
                if v < 0.0 {
                    return PropResult::assert(false, format!("{label} negative: {v}"));
                }
            }
            if trace.len() > 0 && m.total_cost() <= 0.0 {
                return PropResult::assert(false, format!("{} zero cost", kind.name()));
            }
        }
        PropResult::pass()
    });
}

#[test]
fn simulation_is_deterministic() {
    let mut rng = Rng::new(5);
    let trace = synthetic_app("det", &mut rng, 0.65, 400.0, 250.0, 0.010);
    let cfg = SimConfig::paper_default();
    let a = sched::run_scheduler(&SchedulerKind::spork_e(), &trace, &cfg, &defaults());
    let b = sched::run_scheduler(&SchedulerKind::spork_e(), &trace, &cfg, &defaults());
    assert_eq!(a.metrics.total_energy(), b.metrics.total_energy());
    assert_eq!(a.metrics.total_cost(), b.metrics.total_cost());
    assert_eq!(a.metrics.fpga_spinups, b.metrics.fpga_spinups);
}

#[test]
fn hybrid_beats_cpu_only_on_energy_everywhere() {
    // Core paper claim, as a property over random workloads: SporkE is
    // always materially more energy-efficient than CPU-dynamic.
    prop_check(8, |case| {
        let b = case.rng.range_f64(0.5, 0.75);
        let rate = case.rng.range_f64(100.0, 500.0);
        let trace = synthetic_app("prop", &mut case.rng, b, 400.0, rate, 0.010);
        let cfg = SimConfig::paper_default();
        let spork = sched::run_scheduler(&SchedulerKind::spork_e(), &trace, &cfg, &defaults());
        let cpu = sched::run_scheduler(&SchedulerKind::CpuDynamic, &trace, &cfg, &defaults());
        PropResult::assert(
            spork.energy_efficiency() > 1.5 * cpu.energy_efficiency(),
            format!(
                "sporkE {} vs cpu {} at b={b} (seed {})",
                spork.energy_efficiency(),
                cpu.energy_efficiency(),
                case.seed
            ),
        )
    });
}

#[test]
fn deadline_misses_bounded_for_hybrids() {
    // Hybrid schedulers have the CPU escape hatch: misses stay tiny.
    prop_check(8, |case| {
        let b = case.rng.range_f64(0.5, 0.75);
        let trace = synthetic_app("prop", &mut case.rng, b, 300.0, 300.0, 0.010);
        let cfg = SimConfig::paper_default();
        for kind in [
            SchedulerKind::spork_e(),
            SchedulerKind::spork_c(),
            SchedulerKind::MarkIdeal,
        ] {
            let r = sched::run_scheduler(&kind, &trace, &cfg, &defaults());
            if r.miss_fraction() > 0.02 {
                return PropResult::assert(
                    false,
                    format!("{}: {:.2}% misses (seed {})", kind.name(), 100.0 * r.miss_fraction(), case.seed),
                );
            }
        }
        PropResult::pass()
    });
}

#[test]
fn empty_and_degenerate_traces() {
    let cfg = SimConfig::paper_default();
    // Empty trace: no requests, no energy.
    let empty = AppTrace::new("empty", vec![], 100.0);
    let r = sched::run_scheduler(&SchedulerKind::spork_e(), &empty, &cfg, &defaults());
    assert_eq!(r.metrics.requests, 0);
    // Single request.
    let one = AppTrace::new(
        "one",
        vec![Arrival { time: 1.0, size: 0.05 }],
        10.0,
    );
    let r = sched::run_scheduler(&SchedulerKind::spork_e(), &one, &cfg, &defaults());
    assert_eq!(r.metrics.requests, 1);
    assert_eq!(r.metrics.deadline_misses, 0);
}

#[test]
fn worker_caps_respected_under_pressure() {
    prop_check(6, |case| {
        let mut cfg = SimConfig::paper_default();
        cfg.max_cpus = Some(1 + case.rng.below(4) as u32);
        cfg.max_fpgas = Some(1 + case.rng.below(3) as u32);
        let trace = synthetic_app("prop", &mut case.rng, 0.7, 120.0, 300.0, 0.010);
        let r = sched::run_scheduler(&SchedulerKind::spork_e(), &trace, &cfg, &defaults());
        PropResult::assert(
            r.metrics.peak_cpus <= cfg.max_cpus.unwrap()
                && r.metrics.peak_fpgas <= cfg.max_fpgas.unwrap()
                && r.metrics.requests as usize == trace.len(),
            format!(
                "peaks {}/{} vs caps {:?}/{:?}",
                r.metrics.peak_cpus, r.metrics.peak_fpgas, cfg.max_cpus, cfg.max_fpgas
            ),
        )
    });
}
