//! Cross-validation of the three §3 solvers against each other and the
//! Table 3 MILP: trajectory DP == MILP (S=1), rank DP == trajectory DP
//! (S=1), rank DP == MILP with persistence (S>1), homogeneous rank
//! decomposition == DP, and the dominance properties Fig 2 relies on.

use spork::config::PlatformConfig;
use spork::milp::MilpError;
use spork::opt::{dp, rank, ranksolve, FluidInstance, PlatformMode};
use spork::sched::Objective;
use spork::util::prop::{prop_check, PropResult};

fn inst(demand: Vec<f64>, dt: f64) -> FluidInstance {
    FluidInstance {
        demand_f: demand,
        interval: dt,
        platform: PlatformConfig::paper_default(),
    }
}

fn score(obj: Objective, e: f64, c: f64, dt: f64) -> f64 {
    let p = PlatformConfig::paper_default();
    obj.w_energy * e / (p.fpga.busy_power * dt) + obj.w_cost * c / (p.fpga.cost_per_sec() * dt)
}

#[test]
fn dp_matches_milp_randomized() {
    prop_check(8, |case| {
        let t = 3 + case.rng.below(3) as usize;
        let demand: Vec<f64> = (0..t).map(|_| case.rng.below(3) as f64).collect();
        let f = inst(demand.clone(), 10.0);
        for obj in [Objective::energy(), Objective::cost(), Objective::balanced()] {
            let d = dp::solve(&f, PlatformMode::Hybrid, obj);
            let milp = match f.build_milp(PlatformMode::Hybrid, obj).solve(300_000) {
                Ok(m) => m,
                Err(MilpError::NodeLimit) => continue, // rare; skip case
                Err(e) => {
                    return PropResult::assert(false, format!("milp error {e:?} on {demand:?}"))
                }
            };
            let ds = score(obj, d.energy, d.cost, 10.0);
            let p = PropResult::approx_eq(ds, milp.objective, 1e-4, "dp vs milp");
            if !p.ok {
                return PropResult::assert(
                    false,
                    format!("{obj:?} {demand:?}: dp {ds} milp {}", milp.objective),
                );
            }
        }
        PropResult::pass()
    });
}

#[test]
fn ranksolve_matches_milp_with_persistence() {
    prop_check(5, |case| {
        let t = 5 + case.rng.below(2) as usize;
        let s = 2 + case.rng.below(2) as usize;
        let demand: Vec<f64> = (0..t).map(|_| case.rng.below(3) as f64).collect();
        let f = inst(demand.clone(), 1.0);
        let obj = Objective::energy();
        let milp = match f
            .build_milp_persist(PlatformMode::Hybrid, obj, s)
            .solve(500_000)
        {
            Ok(m) => m,
            Err(MilpError::NodeLimit) => return PropResult::pass(),
            Err(e) => return PropResult::assert(false, format!("milp {e:?} on {demand:?}")),
        };
        let r = ranksolve::solve(&f, PlatformMode::Hybrid, obj, s);
        let rs = score(obj, r.energy, r.cost, 1.0);
        PropResult::assert(
            (rs - milp.objective).abs() < 1e-3 * (1.0 + milp.objective),
            format!("S={s} {demand:?}: rank {rs} vs milp {}", milp.objective),
        )
    });
}

#[test]
fn ranksolve_reduces_to_dp_at_s1() {
    prop_check(8, |case| {
        let t = 5 + case.rng.below(20) as usize;
        let demand: Vec<f64> = (0..t)
            .map(|_| case.rng.range_f64(0.0, 5.0).floor())
            .collect();
        let f = inst(demand.clone(), 10.0);
        for (mode, obj) in [
            (PlatformMode::Hybrid, Objective::energy()),
            (PlatformMode::FpgaOnly, Objective::cost()),
        ] {
            let a = ranksolve::solve(&f, mode, obj, 1);
            let b = dp::solve(&f, mode, obj);
            let sa = score(obj, a.energy, a.cost, 10.0);
            let sb = score(obj, b.energy, b.cost, 10.0);
            if (sa - sb).abs() > 1e-6 * (1.0 + sb.abs()) {
                return PropResult::assert(
                    false,
                    format!("{mode:?}: rank {sa} vs dp {sb} on {demand:?}"),
                );
            }
        }
        PropResult::pass()
    });
}

#[test]
fn homogeneous_rank_decomposition_matches_dp() {
    prop_check(8, |case| {
        let t = 5 + case.rng.below(30) as usize;
        let demand: Vec<u32> = (0..t).map(|_| case.rng.below(6) as u32).collect();
        let f = inst(demand.iter().map(|&d| d as f64).collect(), 10.0);
        let d = dp::solve(&f, PlatformMode::FpgaOnly, Objective::energy());
        let r = rank::solve(&demand, &f.platform.fpga, 10.0, true);
        PropResult::approx_eq(d.energy, r.energy(), 1e-9, "dp vs rank energy")
    });
}

#[test]
fn hybrid_dominates_homogeneous_under_persistence() {
    // The Fig 2 dominance property at §3 granularity.
    prop_check(6, |case| {
        let t = 60 + case.rng.below(60) as usize;
        let demand: Vec<f64> = (0..t).map(|_| case.rng.range_f64(0.0, 8.0)).collect();
        let f = inst(demand, 1.0);
        for obj in [Objective::energy(), Objective::cost()] {
            let h = ranksolve::solve(&f, PlatformMode::Hybrid, obj, 10);
            let fo = ranksolve::solve(&f, PlatformMode::FpgaOnly, obj, 10);
            let co = ranksolve::solve(&f, PlatformMode::CpuOnly, obj, 10);
            let sh = score(obj, h.energy, h.cost, 1.0);
            let sf = score(obj, fo.energy, fo.cost, 1.0);
            let sc = score(obj, co.energy, co.cost, 1.0);
            if sh > sf + 1e-6 || sh > sc + 1e-6 {
                return PropResult::assert(
                    false,
                    format!("hybrid dominated: {sh} vs fpga {sf} cpu {sc} (seed {})", case.seed),
                );
            }
        }
        PropResult::pass()
    });
}

#[test]
fn burstier_demand_never_helps_fpga_only() {
    // Monotonicity sanity: concentrating the same volume into fewer slots
    // (a bursty rearrangement) cannot reduce FPGA-only overheads.
    use spork::trace::bmodel;
    use spork::util::rng::Rng;
    let mut rng = Rng::new(4);
    let smooth = inst(vec![4.0; 256], 1.0);
    let bursty = inst(
        bmodel::bmodel_series(&mut rng, 0.72, 256, 4.0 * 256.0),
        1.0,
    );
    let obj = Objective::energy();
    let rs = ranksolve::solve(&smooth, PlatformMode::FpgaOnly, obj, 10);
    let rb = ranksolve::solve(&bursty, PlatformMode::FpgaOnly, obj, 10);
    assert!(
        rb.energy > rs.energy,
        "bursty {} should cost more energy than smooth {}",
        rb.energy,
        rs.energy
    );
}
