//! Scenario subsystem acceptance: (1) the fault-free pack is a true
//! no-op — `run_scheduler_scenario` with it is bit-identical to the
//! pre-scenario path for the full Table-8 roster; (2) scenario sweep
//! grids are bit-deterministic in `--jobs` (fault plans are pure
//! functions of `(seed_base, seed)`, never of thread schedule); (3)
//! under the severe pack every orphaned request is conserved —
//! re-dispatched within its retry budget or recorded as an abandoned
//! deadline miss — and the adversity is non-vacuous.

use spork::config::{PlatformConfig, SchedulerKind, SimConfig};
use spork::exp::{Cell, SweepCell, SweepGrid, WorkloadSpec};
use spork::scenario::ScenarioConfig;
use spork::sched;
use spork::sim::Metrics;
use spork::trace::{synthetic_app, AppTrace};
use spork::util::rng::Rng;

fn workload(seed: u64) -> AppTrace {
    let mut rng = Rng::new(seed);
    synthetic_app("scenario-test", &mut rng, 0.65, 60.0, 60.0, 0.010)
}

/// Every metric the engine accounts, as exact bit patterns — "equal"
/// below means bit-identical, not approximately equal.
fn fingerprint(m: &Metrics) -> Vec<u64> {
    let e = |b: &spork::sim::EnergyBreakdown| {
        [
            b.alloc.to_bits(),
            b.busy.to_bits(),
            b.idle.to_bits(),
            b.dealloc.to_bits(),
        ]
    };
    let mut v = Vec::new();
    v.extend(e(&m.cpu_energy));
    v.extend(e(&m.fpga_energy));
    v.extend([
        m.cpu_cost.to_bits(),
        m.fpga_cost.to_bits(),
        m.requests,
        m.on_cpu,
        m.on_fpga,
        m.deadline_misses,
        m.cpu_spinups,
        m.fpga_spinups,
        m.total_work.to_bits(),
        m.peak_cpus as u64,
        m.peak_fpgas as u64,
        m.completions,
        m.preemptions,
        m.worker_failures,
        m.redispatches,
        m.abandoned,
        m.work_lost.to_bits(),
    ]);
    v
}

#[test]
fn fault_free_pack_is_bit_identical_to_plain_path() {
    // The parity pack plans nothing, so attaching it must change no bit
    // of any metric for any scheduler kind — including the fitted
    // baselines, whose §5.1 searches run fault-free in both paths.
    let trace = workload(3);
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    let pack = ScenarioConfig::fault_free();
    for kind in SchedulerKind::table8_roster() {
        let plain = sched::run_scheduler(&kind, &trace, &cfg, &defaults);
        let scen = sched::run_scheduler_scenario(
            &kind,
            &cfg,
            &defaults,
            &|| Box::new(trace.source()),
            &pack,
            42,
            7,
        );
        assert_eq!(
            fingerprint(&plain.metrics),
            fingerprint(&scen.metrics),
            "{}: fault-free scenario diverged from the plain path",
            kind.name()
        );
        assert_eq!(plain.metrics.requests, plain.metrics.completions);
        assert_eq!(scen.metrics.preemptions, 0);
        assert_eq!(scen.metrics.worker_failures, 0);
        assert_eq!(scen.metrics.abandoned, 0);
    }
}

#[test]
fn scenario_runs_are_reproducible() {
    // Same (pack, seed_base, seed) twice ⇒ identical bits: the fault
    // plan and everything downstream is a pure function of the cell.
    let trace = workload(5);
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    let pack = ScenarioConfig::severe();
    for kind in [SchedulerKind::spork_e(), SchedulerKind::SporkFallback] {
        let run = |seed: u64| {
            sched::run_scheduler_scenario(
                &kind,
                &cfg,
                &defaults,
                &|| Box::new(trace.source()),
                &pack,
                11,
                seed,
            )
        };
        let a = run(0);
        let b = run(0);
        assert_eq!(
            fingerprint(&a.metrics),
            fingerprint(&b.metrics),
            "{}: same cell must replay identically",
            kind.name()
        );
        let c = run(1);
        assert_ne!(
            fingerprint(&a.metrics),
            fingerprint(&c.metrics),
            "{}: the replicate seed must move the fault plan",
            kind.name()
        );
    }
}

fn scenario_grid(jobs: usize) -> Vec<Cell> {
    let roster = [
        SchedulerKind::CpuDynamic,
        SchedulerKind::spork_e(),
        SchedulerKind::GreedySpot,
        SchedulerKind::SporkFallback,
    ];
    let mut grid = SweepGrid::with(2, jobs);
    for pack in [ScenarioConfig::mild(), ScenarioConfig::severe()] {
        for kind in &roster {
            grid.push(SweepCell {
                scheduler: kind.clone(),
                cfg: SimConfig::paper_default(),
                workload: WorkloadSpec {
                    burstiness: 0.65,
                    rate: 80.0,
                    size: 0.010,
                    duration: 120.0,
                },
                seed_base: 81,
                scenario: Some(pack.clone()),
            });
        }
    }
    grid.run()
}

#[test]
fn scenario_grids_are_bit_deterministic_in_jobs() {
    // The sweep determinism contract must survive fault injection: plans
    // derive from `(seed_base, seed)`, never from which worker thread
    // runs the replicate.
    let serial = scenario_grid(1);
    for jobs in [2, 0] {
        assert_eq!(
            serial,
            scenario_grid(jobs),
            "jobs={jobs} diverged under faults"
        );
    }
}

#[test]
fn severe_faults_conserve_every_request() {
    // Kill accounting closes: arrivals == completions + abandoned, every
    // abandonment is a deadline miss, and the pack actually bites.
    let trace = workload(9);
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    let pack = ScenarioConfig::severe();
    let mut total_faults = 0u64;
    for kind in SchedulerKind::scenario_roster() {
        let r = sched::run_scheduler_scenario(
            &kind,
            &cfg,
            &defaults,
            &|| Box::new(trace.source()),
            &pack,
            1,
            0,
        );
        let m = &r.metrics;
        assert_eq!(m.requests as usize, trace.len(), "{}: lost arrivals", kind.name());
        assert_eq!(
            m.requests,
            m.completions + m.abandoned,
            "{}: conservation violated",
            kind.name()
        );
        assert!(
            m.abandoned <= m.deadline_misses,
            "{}: every abandonment must count as a miss",
            kind.name()
        );
        assert!(m.work_lost >= 0.0 && m.work_lost.is_finite());
        if m.preemptions + m.worker_failures == 0 {
            assert_eq!(
                m.redispatches + m.abandoned,
                0,
                "{}: retries without a kill",
                kind.name()
            );
            assert!((m.work_lost - 0.0).abs() < 1e-12);
        }
        total_faults += m.preemptions + m.worker_failures;
    }
    assert!(
        total_faults > 0,
        "severe pack injected nothing across the whole roster (vacuous)"
    );
}

#[test]
fn greedy_spot_takes_real_preemptions_under_severe() {
    // The all-spot baseline keeps FPGAs alive for the whole run, so the
    // severe pack's strike process must land on live victims.
    let trace = workload(13);
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    let r = sched::run_scheduler_scenario(
        &SchedulerKind::GreedySpot,
        &cfg,
        &defaults,
        &|| Box::new(trace.source()),
        &ScenarioConfig::severe(),
        1,
        0,
    );
    let m = &r.metrics;
    assert!(m.preemptions > 0, "no strikes landed: {m:?}");
    assert!(
        m.redispatches + m.abandoned > 0,
        "strikes landed but nothing was re-offered or abandoned: {m:?}"
    );
    assert_eq!(m.requests, m.completions + m.abandoned);
    assert!(m.fpga_cost > 0.0, "spot billing must accrue cost");
}
