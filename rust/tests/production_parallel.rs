//! Parity pins for the intra-run parallelism work (DESIGN.md §14): a
//! production cell — and a sweep cell, and a fitting search — must be
//! **bit-identical** for any `--jobs` value. Scheduling order may vary
//! between runs; results may not.
//!
//! 1. **Per-app fan-out parity** — `run_production_jobs` and
//!    `run_production_profiles_jobs` over the full Table-8 roster must
//!    produce the same `Cell` at jobs 1 (forced-serial reference), 2,
//!    and 0 (full executor budget).
//! 2. **Scenario cells too** — a `SweepGrid` cell with a fault pack
//!    attached replays deterministic per-(seed, kind) fault plans; the
//!    grid must stay bit-identical across jobs values with the per-app
//!    level drawing from the same permit pool.
//! 3. **Fit plan parity** — the lockstep engine (which now runs its
//!    candidate batches concurrently over per-candidate fresh streams
//!    when the executor grants permits, and falls back to the shared
//!    tee otherwise) must still equal the serial gallop+bisect engine
//!    run-for-run. The plan-vs-plan equivalence itself is pinned by an
//!    in-crate unit test against private executors
//!    (`candidate_batch_plans_are_bit_identical`); this asserts the
//!    user-visible contract end to end.

use spork::config::{PlatformConfig, SchedulerKind, SimConfig, SizeBucket};
use spork::exp::common::{
    profile_apps, run_production_jobs, run_production_profiles_jobs,
};
use spork::exp::{SweepCell, SweepGrid, WorkloadSpec};
use spork::scenario::ScenarioConfig;
use spork::sched::{fpga_dynamic, fpga_static, FitEngine};
use spork::trace::production::{self, Dataset, ProductionParams};
use spork::trace::{synthetic_app, AppTrace};
use spork::util::rng::Rng;

fn production_apps(scale: f64, max_apps: usize, seed: u64) -> Vec<AppTrace> {
    let params = ProductionParams {
        dataset: Dataset::AzureFunctions,
        bucket: SizeBucket::Short,
        duration: 600.0,
        scale,
        max_apps: Some(max_apps),
    };
    production::generate(&params, &mut Rng::new(seed))
}

#[test]
fn production_cells_bit_identical_for_any_jobs() {
    let cfg = SimConfig::paper_default();
    let apps = production_apps(0.2, 3, 11);
    assert!(!apps.is_empty(), "parity over an empty roster proves nothing");
    let profiles = profile_apps(apps.clone(), &cfg);
    for kind in SchedulerKind::table8_roster() {
        let direct_serial = run_production_jobs(&kind, &cfg, &apps, 1);
        let profiled_serial = run_production_profiles_jobs(&kind, &cfg, &profiles, 1);
        for jobs in [2usize, 0] {
            assert_eq!(
                run_production_jobs(&kind, &cfg, &apps, jobs),
                direct_serial,
                "{}: per-app path diverged at jobs={jobs}",
                kind.name()
            );
            assert_eq!(
                run_production_profiles_jobs(&kind, &cfg, &profiles, jobs),
                profiled_serial,
                "{}: profile path diverged at jobs={jobs}",
                kind.name()
            );
        }
    }
}

#[test]
fn scenario_sweep_cell_bit_identical_for_any_jobs() {
    // Fault plans are synthesized per (cell, seed) from pure RNG streams,
    // so a scenario cell has the same any-jobs contract as a fault-free
    // one — worth pinning separately because the scenario path routes
    // through `run_scheduler_scenario`'s re-dispatch machinery.
    let cfg = SimConfig::paper_default();
    let cell = |kind: SchedulerKind, scenario: Option<ScenarioConfig>| SweepCell {
        scheduler: kind,
        cfg: cfg.clone(),
        workload: WorkloadSpec {
            burstiness: 0.65,
            rate: 150.0,
            size: 0.010,
            duration: 180.0,
        },
        seed_base: 41,
        scenario,
    };
    let cells = vec![
        cell(SchedulerKind::spork_e(), Some(ScenarioConfig::mild())),
        cell(SchedulerKind::spork_e(), Some(ScenarioConfig::severe())),
        cell(SchedulerKind::FpgaDynamic, None),
    ];
    let run_at = |jobs: usize| {
        let mut grid = SweepGrid::with(2, jobs);
        for c in &cells {
            grid.push(c.clone());
        }
        grid.run()
    };
    let reference = run_at(1);
    assert!(
        reference
            .iter()
            .take(2)
            .any(|c| c.preemptions + c.worker_failures > 0.0),
        "adverse packs injected nothing — the scenario leg of this parity \
         test would be vacuous"
    );
    for jobs in [2usize, 0] {
        assert_eq!(
            run_at(jobs),
            reference,
            "scenario sweep diverged from serial at jobs={jobs}"
        );
    }
}

#[test]
fn lockstep_parallel_fit_equals_serial_engine_end_to_end() {
    // Run under the real global executor (whatever budget the test host
    // grants — possibly contended by other tests, possibly serial): the
    // lockstep engine must land on the same fitted value and the same
    // bit-identical winning run as the serial engine either way. That
    // "either way" is the point — which plan executed must be
    // unobservable in the results.
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    let mut rng = Rng::new(27);
    let trace = synthetic_app("pp", &mut rng, 0.68, 240.0, 220.0, 0.010);
    for tol in [0.005, 0.02] {
        let (sr, sk, _) = fpga_dynamic::fit_source_stats_with(
            FitEngine::Serial,
            &|| Box::new(trace.source()),
            &cfg,
            &defaults,
            tol,
        );
        let (lr, lk, _) = fpga_dynamic::fit_source_stats_with(
            FitEngine::Lockstep,
            &|| Box::new(trace.source()),
            &cfg,
            &defaults,
            tol,
        );
        assert_eq!(sk, lk, "tol {tol}: dynamic fitted k diverged");
        assert_eq!(sr.metrics.requests, lr.metrics.requests);
        assert_eq!(sr.metrics.deadline_misses, lr.metrics.deadline_misses);
        assert_eq!(
            sr.metrics.total_energy().to_bits(),
            lr.metrics.total_energy().to_bits(),
            "tol {tol}: dynamic energy diverged"
        );
        assert_eq!(
            sr.metrics.total_cost().to_bits(),
            lr.metrics.total_cost().to_bits(),
            "tol {tol}: dynamic cost diverged"
        );

        let (sr, sfleet, _) = fpga_static::fit_source_stats_with(
            FitEngine::Serial,
            &|| Box::new(trace.source()),
            &cfg,
            &defaults,
            tol,
        );
        let (lr, lfleet, _) = fpga_static::fit_source_stats_with(
            FitEngine::Lockstep,
            &|| Box::new(trace.source()),
            &cfg,
            &defaults,
            tol,
        );
        assert_eq!(sfleet, lfleet, "tol {tol}: static fitted fleet diverged");
        assert_eq!(sr.metrics.requests, lr.metrics.requests);
        assert_eq!(sr.metrics.deadline_misses, lr.metrics.deadline_misses);
        assert_eq!(
            sr.metrics.total_energy().to_bits(),
            lr.metrics.total_energy().to_bits(),
            "tol {tol}: static energy diverged"
        );
        assert_eq!(
            sr.metrics.total_cost().to_bits(),
            lr.metrics.total_cost().to_bits(),
            "tol {tol}: static cost diverged"
        );
    }
}
