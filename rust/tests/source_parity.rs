//! Source/materialized parity: every streaming [`ArrivalSource`] must
//! yield *exactly* the sequence its old `Vec`-building counterpart
//! produces for the same `(seed_base, seed)` RNG stream — the contract
//! that makes the streaming refactor decision-stream-preserving (a
//! driver fed by a source sees the same arrivals, so every policy makes
//! the same decisions and Table 8/9 outputs stay byte-identical).
//!
//! Property-test style: each pairing is replayed across a grid of seeds
//! with seed-derived parameters, not a single hand-picked case.

use spork::config::{PlatformConfig, SchedulerKind, SimConfig, SizeBucket};
use spork::trace::production::{self, Dataset, ProductionParams};
use spork::trace::{
    self, poisson, synthetic_source, AppTrace, Arrival, ArrivalSource, MergeSource, RateTrace,
    TeeSource, TraceSource,
};
use spork::util::rng::Rng;

fn drain(src: &mut dyn ArrivalSource) -> Vec<Arrival> {
    std::iter::from_fn(|| src.next_arrival()).collect()
}

#[test]
fn poisson_source_matches_vec_builder_across_seeds() {
    for seed in 0..12u64 {
        // Seed-derived rate shapes, including zero-rate and bursty slots.
        let mut shape_rng = Rng::for_stream(100, seed);
        let slots = 3 + shape_rng.below(40) as usize;
        let rates: Vec<f64> = (0..slots)
            .map(|_| {
                if shape_rng.chance(0.2) {
                    0.0
                } else {
                    shape_rng.range_f64(0.0, 120.0)
                }
            })
            .collect();
        let dt = *shape_rng.choose(&[1.0, 5.0, 60.0]);
        let rates = RateTrace::new(dt, rates);
        let expect =
            poisson::poisson_arrivals(&mut Rng::for_stream(7, seed), &rates, |t| 0.01 + t * 1e-6);
        let mut src = spork::trace::PoissonSource::new(
            "p",
            Rng::for_stream(7, seed),
            rates.clone(),
            rates.duration(),
            Box::new(|t| 0.01 + t * 1e-6),
        );
        assert_eq!(drain(&mut src), expect, "seed {seed} diverged");
    }
}

#[test]
fn synthetic_source_matches_synthetic_app_across_seeds() {
    for seed in 0..10u64 {
        let mut p = Rng::for_stream(200, seed);
        let burstiness = p.range_f64(0.5, 0.749);
        let duration = p.range_f64(61.0, 400.0);
        let rate = p.range_f64(5.0, 150.0);
        let size = p.range_f64(0.005, 0.05);
        let dt = *p.choose(&[1.0, 60.0]);

        let expect = trace::synthetic_app_dt(
            "s",
            &mut Rng::for_stream(31, seed),
            burstiness,
            duration,
            rate,
            size,
            dt,
        );
        let mut src = synthetic_source(
            "s",
            Rng::for_stream(31, seed),
            burstiness,
            duration,
            rate,
            size,
            dt,
        );
        assert_eq!(src.duration(), expect.duration);
        assert_eq!(drain(&mut src), expect.arrivals, "seed {seed} diverged");
    }
}

#[test]
fn production_sources_match_generate() {
    for (seed, dataset) in [
        (1u64, Dataset::AzureFunctions),
        (2, Dataset::AlibabaMicroservices),
        (3, Dataset::AzureFunctions),
    ] {
        let params = ProductionParams {
            dataset,
            bucket: SizeBucket::Short,
            duration: 900.0,
            scale: 0.2,
            max_apps: Some(5),
        };
        let apps = production::generate(&params, &mut Rng::new(seed));
        let sources = production::app_sources(&params, &mut Rng::new(seed));
        assert_eq!(apps.len(), sources.len());
        for (app, mut src) in apps.into_iter().zip(sources) {
            assert_eq!(src.name(), app.name);
            assert_eq!(src.duration(), app.duration);
            assert_eq!(drain(&mut src), app.arrivals, "{} diverged", app.name);
        }
    }
}

#[test]
fn collect_adapter_round_trips() {
    let expect = trace::synthetic_app("rt", &mut Rng::new(5), 0.6, 120.0, 40.0, 0.010);
    let mut src = synthetic_source("rt", Rng::new(5), 0.6, 120.0, 40.0, 0.010, 60.0);
    let collected = AppTrace::from_source(&mut src);
    assert_eq!(collected.name, expect.name);
    assert_eq!(collected.duration, expect.duration);
    assert_eq!(collected.arrivals, expect.arrivals);
}

#[test]
fn merge_source_equals_stable_sorted_concat() {
    for seed in 0..6u64 {
        let traces: Vec<AppTrace> = (0..4)
            .map(|i| {
                trace::synthetic_app_dt(
                    &format!("app{i}"),
                    &mut Rng::for_stream(seed, i),
                    0.6,
                    60.0,
                    20.0 + 10.0 * i as f64,
                    0.010,
                    60.0,
                )
            })
            .collect();
        // Reference: stable sort of the concatenation (ties keep source
        // order, matching the merge's by-source-index tiebreak).
        let mut expect: Vec<Arrival> = traces.iter().flat_map(|t| t.arrivals.clone()).collect();
        expect.sort_by(|a, b| a.time.total_cmp(&b.time));
        let sources: Vec<Box<dyn ArrivalSource>> = traces
            .iter()
            .map(|t| Box::new(TraceSource::new(t)) as Box<dyn ArrivalSource>)
            .collect();
        let mut merged = MergeSource::new("all", sources);
        assert_eq!(merged.duration(), 60.0);
        assert_eq!(drain(&mut merged), expect, "seed {seed} diverged");
    }
}

#[test]
fn streaming_run_equals_materialized_run() {
    // The end-to-end consequence: driving the sim from a source produces
    // byte-identical results to driving it from the materialized trace —
    // for a reactive kind, an oracle kind, and a fitted kind (which
    // re-streams the workload through its fitting search).
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    for kind in [
        SchedulerKind::spork_e(),
        SchedulerKind::MarkIdeal,
        SchedulerKind::FpgaDynamic,
    ] {
        for seed in 0..3u64 {
            let trace = trace::synthetic_app(
                "par",
                &mut Rng::for_stream(50, seed),
                0.65,
                180.0,
                80.0,
                0.010,
            );
            let via_trace = spork::sched::run_scheduler(&kind, &trace, &cfg, &defaults);
            let via_source = spork::sched::run_scheduler_source(&kind, &cfg, &defaults, &|| {
                Box::new(synthetic_source(
                    "par",
                    Rng::for_stream(50, seed),
                    0.65,
                    180.0,
                    80.0,
                    0.010,
                    60.0,
                ))
            });
            assert_eq!(via_trace.metrics.requests, via_source.metrics.requests);
            assert_eq!(
                via_trace.metrics.deadline_misses, via_source.metrics.deadline_misses,
                "{} seed {seed}",
                kind.name()
            );
            assert_eq!(
                via_trace.metrics.total_energy(),
                via_source.metrics.total_energy(),
                "{} seed {seed}",
                kind.name()
            );
            assert_eq!(
                via_trace.metrics.total_cost(),
                via_source.metrics.total_cost(),
                "{} seed {seed}",
                kind.name()
            );
            assert_eq!(via_trace.metrics.fpga_spinups, via_source.metrics.fpga_spinups);
            assert_eq!(via_trace.metrics.cpu_spinups, via_source.metrics.cpu_spinups);
        }
    }
}

// ---- tee fan-out properties -------------------------------------------
//
// The lockstep fitting engine fans one stream out to N concurrent
// consumers via `trace::tee`. The property that makes lockstep
// bit-identical to serial fitting: **every consumer observes exactly the
// serial stream** — same arrivals, same order, same count, bit for bit —
// no matter how consumer pulls interleave, and no matter which siblings
// drop out early (aborted candidates). Replayed here across seeds and
// seed-derived interleavings for each source family the fitting searches
// actually stream: PoissonSource (synthetic), MergeSource (multi-app),
// CsvSource (saved traces).

/// Drive tee consumers with a seed-derived random interleaving, dropping
/// consumer `i` after `drop_after[i]` pulls (None = let it finish), and
/// assert every survivor saw exactly `expect` and every dropped consumer
/// saw exactly the matching prefix.
fn assert_tee_consumers_match_serial(
    expect: &[Arrival],
    consumers: Vec<TeeSource<'_>>,
    seed: u64,
    drop_after: &[Option<usize>],
) {
    struct Slot<'a> {
        src: TeeSource<'a>,
        got: Vec<Arrival>,
        done: bool,
    }
    let n = consumers.len();
    assert_eq!(drop_after.len(), n);
    let mut rng = Rng::for_stream(9000, seed);
    let mut slots: Vec<Option<Slot>> = consumers
        .into_iter()
        .map(|src| {
            Some(Slot {
                src,
                got: Vec::new(),
                done: false,
            })
        })
        .collect();
    let mut survivors = 0usize;
    loop {
        let live: Vec<usize> = (0..n)
            .filter(|&i| slots[i].as_ref().is_some_and(|s| !s.done))
            .collect();
        if live.is_empty() {
            break;
        }
        let i = live[rng.below(live.len() as u64) as usize];
        let slot = slots[i].as_mut().unwrap();
        match slot.src.next_arrival() {
            Some(a) => slot.got.push(a),
            None => {
                slot.done = true;
                // Exhaustion is stable: further pulls keep yielding None.
                assert!(slot.src.next_arrival().is_none(), "consumer {i} resurrected");
            }
        }
        if !slot.done && drop_after[i] == Some(slot.got.len()) {
            // Early drop (an aborted lockstep candidate): the prefix seen
            // so far must already match, and the drop must not perturb
            // the siblings — checked implicitly by their own asserts.
            assert_eq!(
                &slot.got[..],
                &expect[..slot.got.len()],
                "dropped consumer {i} (seed {seed}): prefix diverged"
            );
            slots[i] = None;
        }
    }
    for (i, slot) in slots.into_iter().enumerate() {
        if let Some(s) = slot {
            assert_eq!(
                s.got, expect,
                "consumer {i} (seed {seed}) diverged from the serial stream"
            );
            survivors += 1;
        }
    }
    assert!(survivors >= 1, "at least one consumer must run to completion");
}

/// Seed-derived drop plan: on odd seeds, one consumer aborts a third of
/// the way through the stream (never the designated survivor, consumer
/// n-1).
fn drop_plan(seed: u64, n: usize, stream_len: usize) -> Vec<Option<usize>> {
    let mut plan = vec![None; n];
    if seed % 2 == 1 && stream_len >= 3 && n >= 2 {
        plan[(seed as usize) % (n - 1)] = Some((stream_len / 3).max(1));
    }
    plan
}

#[test]
fn tee_over_poisson_source_matches_serial_across_seeds() {
    for seed in 0..10u64 {
        let mut shape_rng = Rng::for_stream(300, seed);
        let slots = 3 + shape_rng.below(30) as usize;
        let rates: Vec<f64> = (0..slots)
            .map(|_| {
                if shape_rng.chance(0.2) {
                    0.0
                } else {
                    shape_rng.range_f64(0.0, 80.0)
                }
            })
            .collect();
        let dt = *shape_rng.choose(&[1.0, 5.0]);
        let rates = RateTrace::new(dt, rates);
        let make = || {
            spork::trace::PoissonSource::new(
                "p",
                Rng::for_stream(8, seed),
                rates.clone(),
                rates.duration(),
                Box::new(|t| 0.01 + t * 1e-6),
            )
        };
        let expect = drain(&mut make());
        let n = 2 + (seed as usize) % 3;
        let consumers = trace::tee(Box::new(make()), n);
        let plan = drop_plan(seed, n, expect.len());
        assert_tee_consumers_match_serial(&expect, consumers, seed, &plan);
    }
}

#[test]
fn tee_over_merge_source_matches_serial_across_seeds() {
    for seed in 0..6u64 {
        let traces: Vec<AppTrace> = (0..3)
            .map(|i| {
                trace::synthetic_app_dt(
                    &format!("app{i}"),
                    &mut Rng::for_stream(seed, i),
                    0.6,
                    60.0,
                    15.0 + 10.0 * i as f64,
                    0.010,
                    60.0,
                )
            })
            .collect();
        let make = |traces: &[AppTrace]| {
            let sources: Vec<Box<dyn ArrivalSource>> = traces
                .iter()
                .map(|t| Box::new(t.clone().into_source()) as Box<dyn ArrivalSource>)
                .collect();
            MergeSource::new("all", sources)
        };
        let expect = drain(&mut make(&traces));
        let n = 3;
        let consumers = trace::tee(Box::new(make(&traces)), n);
        let plan = drop_plan(seed, n, expect.len());
        assert_tee_consumers_match_serial(&expect, consumers, seed, &plan);
    }
}

#[test]
fn tee_over_csv_source_matches_serial() {
    let dir = std::env::temp_dir().join(format!("spork-tee-csv-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("teed.csv");
    for seed in 0..4u64 {
        let app = trace::synthetic_app(
            "teed",
            &mut Rng::for_stream(400, seed),
            0.6,
            90.0,
            25.0,
            0.010,
        );
        spork::trace::io::save_csv(&app, &path).unwrap();
        // CSV round-trips at {:.6} precision; the serial reference is the
        // re-parsed stream, so consumers are compared bit-for-bit against
        // what the file actually yields.
        let expect = drain(&mut spork::trace::CsvSource::open(&path).unwrap());
        let n = 2 + (seed as usize) % 2;
        let consumers = trace::tee(
            Box::new(spork::trace::CsvSource::open(&path).unwrap()),
            n,
        );
        let plan = drop_plan(seed, n, expect.len());
        assert_tee_consumers_match_serial(&expect, consumers, seed, &plan);
    }
    std::fs::remove_dir_all(&dir).ok();
}
