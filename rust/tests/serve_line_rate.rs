//! Line-rate serving suite: the batched, paced, sharded router must be a
//! pure *scheduling* change, never a *behavior* change.
//!
//! Three contracts from DESIGN.md §13:
//!
//! 1. **Batched admission parity** — the paced router (absolute-deadline
//!    sleeps + `step_until` horizon drains) executes the bit-identical
//!    effect stream as per-arrival stepping: batching amortizes syscalls,
//!    the model never sees it.
//! 2. **Shed conservation** — with a bounded admission queue, every
//!    arrival is accounted for exactly once:
//!    `requests == dispatched + shed` (and shed is zero when the cap is
//!    unarmed or never reached).
//! 3. **Shard-count determinism** — partitioning the app set across any
//!    number of router shards merges to the bit-identical report.

use spork::config::SchedulerKind;
use spork::policy::Effect;
use spork::sched;
use spork::serve::{
    run_serve_policy, run_serve_sharded, AppFactory, AppServe, Compute, ServeConfig,
};
use spork::trace::{synthetic_app, AppTrace};
use spork::util::rng::Rng;

const POOL_CPUS: usize = 8;
const POOL_FPGAS: usize = 4;

fn line_trace() -> AppTrace {
    let mut rng = Rng::new(77);
    synthetic_app("line", &mut rng, 0.6, 120.0, 60.0, 0.010)
}

/// High compression: 120 sim-s replays in well under a wall second, so
/// the paced path exercises its sleeps without slowing the suite.
fn cfg_at(queue_cap: usize) -> ServeConfig {
    let mut cfg = ServeConfig::defaults("unused-artifacts", 1e5);
    cfg.pool_cpus = POOL_CPUS;
    cfg.pool_fpgas = POOL_FPGAS;
    cfg.queue_cap = queue_cap;
    cfg
}

fn run(
    compute: Compute,
    queue_cap: usize,
    trace: &AppTrace,
) -> (spork::serve::ServeReport, Vec<Effect>) {
    let cfg = cfg_at(queue_cap);
    let sim_cfg = cfg.sim_config(POOL_CPUS, POOL_FPGAS);
    let mut policy = sched::build(&SchedulerKind::spork_e(), &sim_cfg, trace);
    let mut rng = Rng::new(3);
    let mut log = Vec::new();
    let (report, _) = run_serve_policy(&cfg, policy.as_mut(), trace, &mut rng, compute, &mut |e| {
        log.push(*e)
    })
    .expect("stubbed/paced serve cannot fail");
    (report, log)
}

#[test]
fn batched_paced_replay_is_bit_identical_to_per_arrival_stepping() {
    let trace = line_trace();
    let (stub_report, stub_log) = run(Compute::Stub, 0, &trace);
    let (paced_report, paced_log) = run(Compute::Paced, 0, &trace);

    assert!(!stub_log.is_empty(), "workload produced no effects");
    assert_eq!(
        stub_log.len(),
        paced_log.len(),
        "effect counts diverge (per-arrival {} vs batched {})",
        stub_log.len(),
        paced_log.len()
    );
    for (i, (a, b)) in stub_log.iter().zip(&paced_log).enumerate() {
        assert_eq!(a, b, "batched admission diverges at effect #{i}");
    }

    // Model-side accounting identical; only wall-clock fields may differ.
    assert_eq!(stub_report.requests, paced_report.requests);
    assert_eq!(stub_report.on_cpu, paced_report.on_cpu);
    assert_eq!(stub_report.on_fpga, paced_report.on_fpga);
    assert_eq!(stub_report.misses, paced_report.misses);
    assert_eq!(stub_report.shed, 0);
    assert_eq!(paced_report.shed, 0);
    assert_eq!(
        stub_report.energy_j.to_bits(),
        paced_report.energy_j.to_bits(),
        "energy accounting must not depend on pacing"
    );
    assert_eq!(
        stub_report.cost_usd.to_bits(),
        paced_report.cost_usd.to_bits()
    );
    assert_eq!(
        stub_report.latency_ms.count(),
        paced_report.latency_ms.count()
    );
    assert_eq!(
        stub_report.latency_ms.percentile(99.0).to_bits(),
        paced_report.latency_ms.percentile(99.0).to_bits()
    );
}

#[test]
fn unreached_queue_cap_is_bit_identical_to_unbounded() {
    // An armed-but-generous cap must not perturb a single decision.
    let trace = line_trace();
    let (unbounded_report, unbounded_log) = run(Compute::Stub, 0, &trace);
    let (capped_report, capped_log) = run(Compute::Stub, 100_000, &trace);
    assert_eq!(capped_report.shed, 0, "a 100k cap cannot bite here");
    assert_eq!(unbounded_log, capped_log);
    assert_eq!(unbounded_report.requests, capped_report.requests);
    assert_eq!(
        unbounded_report.energy_j.to_bits(),
        capped_report.energy_j.to_bits()
    );
}

#[test]
fn tight_queue_cap_sheds_and_conserves_every_arrival() {
    let trace = line_trace();
    let (report, log) = run(Compute::Stub, 2, &trace);

    let dispatched = log
        .iter()
        .filter(|e| matches!(e, Effect::Dispatched { .. }))
        .count() as u64;
    let shed = log
        .iter()
        .filter(|e| matches!(e, Effect::Shed { .. }))
        .count() as u64;

    assert!(report.shed > 0, "a cap of 2 in-flight must shed this load");
    assert!(dispatched > 0, "some requests must still be admitted");
    assert_eq!(report.shed, shed, "report must count exactly the Shed effects");
    assert_eq!(
        report.requests,
        dispatched + shed,
        "conservation: every arrival is dispatched or shed, never both, \
         never neither"
    );
    assert_eq!(
        report.requests as usize,
        trace.len(),
        "shed arrivals still count as offered requests"
    );
    assert_eq!(
        report.latency_ms.count(),
        dispatched,
        "latency histogram covers exactly the dispatched requests"
    );
}

fn app_factory(i: usize) -> AppFactory {
    Box::new(move || {
        // Pure function of the app index — the shard determinism contract.
        let mut rng = Rng::for_stream(91, i as u64);
        let trace = synthetic_app(
            &format!("app{i}"),
            &mut rng,
            0.6,
            90.0,
            15.0 + 10.0 * i as f64,
            0.010,
        );
        let cfg = ServeConfig::defaults("unused-artifacts", 1e5);
        let sim_cfg = cfg.sim_config(POOL_CPUS, POOL_FPGAS);
        let policy = sched::build(&SchedulerKind::spork_e(), &sim_cfg, &trace);
        AppServe {
            source: Box::new(trace.into_source()),
            policy,
            pool_cpus: POOL_CPUS,
            pool_fpgas: POOL_FPGAS,
        }
    })
}

#[test]
fn shard_count_never_changes_the_paced_merged_report() {
    // The end-to-end (paced, wall-clock, multi-threaded) version of the
    // stub-compute unit test in serve::shard: wall time affects nothing
    // the model computes, so even racing shard threads merge identically.
    let cfg = cfg_at(256);
    let run = |shards: usize| {
        let apps: Vec<AppFactory> = (0..6).map(app_factory).collect();
        run_serve_sharded(&cfg, apps, shards, Compute::Paced).unwrap()
    };
    let one = run(1);
    assert!(one.requests > 1000, "workload too small to mean anything");
    assert_eq!(one.shed, 0, "per-app pools keep a 256 cap quiet");
    for shards in [2, 4] {
        let many = run(shards);
        assert_eq!(one.requests, many.requests, "{shards} shards");
        assert_eq!(one.on_cpu, many.on_cpu);
        assert_eq!(one.on_fpga, many.on_fpga);
        assert_eq!(one.misses, many.misses);
        assert_eq!(one.shed, many.shed);
        assert_eq!(
            one.energy_j.to_bits(),
            many.energy_j.to_bits(),
            "energy must merge identically at {shards} shards"
        );
        assert_eq!(one.cost_usd.to_bits(), many.cost_usd.to_bits());
        assert_eq!(one.sim_seconds.to_bits(), many.sim_seconds.to_bits());
        assert_eq!(one.latency_ms.count(), many.latency_ms.count());
        assert_eq!(
            one.latency_ms.percentile(50.0).to_bits(),
            many.latency_ms.percentile(50.0).to_bits()
        );
        assert_eq!(
            one.latency_ms.percentile(99.9).to_bits(),
            many.latency_ms.percentile(99.9).to_bits()
        );
    }
}
