//! Smoke test for the serving runtime with *real* compute: a short
//! scaled-time run through the full three-layer stack, SporkE driving the
//! warm PJRT pool via the real-time driver. Gated on artifacts (run
//! `make artifacts`); the artifact-free serve path is covered by
//! `policy_parity.rs` and the in-module stub tests.

use spork::serve::{run_serve_trace, ServeConfig};
use spork::trace::synthetic_app_dt;
use spork::util::rng::Rng;

fn artifacts_exist() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

#[test]
fn serve_end_to_end_smoke() {
    if !artifacts_exist() {
        eprintln!("SKIP: artifacts/ not built (run `make artifacts`)");
        return;
    }
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let mut cfg = ServeConfig::defaults(dir.to_str().unwrap(), 10.0);
    cfg.pool_cpus = 3;
    cfg.pool_fpgas = 2;
    let mut rng = Rng::new(3);
    // 40 simulated seconds (4 wall-s), modest load.
    let trace = synthetic_app_dt("smoke", &mut rng, 0.55, 40.0, 30.0, 0.010, 20.0);
    let (report, completions) = run_serve_trace(&cfg, &trace, &mut rng).unwrap();

    assert_eq!(report.requests as usize, trace.len(), "lost requests");
    assert_eq!(report.on_cpu + report.on_fpga, report.requests);
    assert_eq!(completions.len(), trace.len());
    assert_eq!(report.scheduler, "spork-e");
    // Real compute happened: outputs are not all identical/zero.
    let distinct: std::collections::HashSet<u32> = completions
        .iter()
        .map(|c| c.output0.to_bits())
        .collect();
    assert!(distinct.len() > 10, "outputs look constant: {}", distinct.len());
    // Completion timestamps are on the shared clock and ordered sanely.
    for c in &completions {
        assert!(c.finish_sim >= c.arrival_sim, "negative latency");
        assert!(c.finish_sim <= report.sim_seconds + 60.0);
    }
    // Energy/cost accounting produced something plausible.
    assert!(report.energy_j > 0.0);
    assert!(report.cost_usd > 0.0);
}
