//! Serve-path resilience suite (DESIGN.md §15).
//!
//! Three contracts:
//!
//! 1. **Chaos-off bit parity** — attaching the `fault-free` pack (empty
//!    plan, disabled recovery decorator) to a serving run changes
//!    *nothing*: the effect stream and every model-side report field are
//!    bit-identical to a plain run, for the whole Table 8 roster. The
//!    resilience subsystem is pay-for-what-you-break.
//! 2. **Severe-pack non-vacuity and conservation** — the `severe` pack
//!    must actually kill workers and force retries, and the extended
//!    conservation law `requests == completions + shed + abandoned` must
//!    hold exactly (retries re-dispatch an admitted request, never mint a
//!    new one), with `hedge_wins <= hedges`.
//! 3. **Sharded chaos determinism** — per-app fault plans are seeded by
//!    the app index, so a chaotic sharded run merges to the bit-identical
//!    report (and plan digest) for any shard count.

use spork::config::SchedulerKind;
use spork::policy::Effect;
use spork::sched;
use spork::serve::{
    run_serve_policy, run_serve_sharded, AppFactory, AppServe, ChaosSpec, Compute, ServeConfig,
    ServeReport,
};
use spork::trace::{synthetic_app, AppTrace};
use spork::util::rng::Rng;

const POOL_CPUS: usize = 8;
const POOL_FPGAS: usize = 4;

fn chaos_trace(duration: f64) -> AppTrace {
    let mut rng = Rng::new(913);
    synthetic_app("chaos", &mut rng, 0.6, duration, 60.0, 0.010)
}

fn cfg_with(chaos: Option<ChaosSpec>) -> ServeConfig {
    let mut cfg = ServeConfig::defaults("unused-artifacts", 1e5);
    cfg.pool_cpus = POOL_CPUS;
    cfg.pool_fpgas = POOL_FPGAS;
    cfg.chaos = chaos;
    cfg
}

fn run(kind: &SchedulerKind, chaos: Option<ChaosSpec>, trace: &AppTrace) -> (ServeReport, Vec<Effect>) {
    let cfg = cfg_with(chaos);
    let sim_cfg = cfg.sim_config(POOL_CPUS, POOL_FPGAS);
    let mut policy = sched::build(kind, &sim_cfg, trace);
    let mut rng = Rng::new(3);
    let mut log = Vec::new();
    let (report, _) =
        run_serve_policy(&cfg, policy.as_mut(), trace, &mut rng, Compute::Stub, &mut |e| {
            log.push(*e)
        })
        .expect("stubbed serve cannot fail");
    (report, log)
}

#[test]
fn fault_free_chaos_is_bit_identical_to_no_chaos_for_the_roster() {
    let trace = chaos_trace(120.0);
    for kind in SchedulerKind::table8_roster() {
        let (plain, plain_log) = run(&kind, None, &trace);
        let spec = ChaosSpec::from_name("fault-free", 1, 0).expect("parity pack exists");
        let (wrapped, wrapped_log) = run(&kind, Some(spec), &trace);

        assert!(!plain_log.is_empty(), "{}: workload produced no effects", kind.name());
        assert_eq!(
            plain_log.len(),
            wrapped_log.len(),
            "{}: effect counts diverge under the parity pack",
            kind.name()
        );
        for (i, (a, b)) in plain_log.iter().zip(&wrapped_log).enumerate() {
            assert_eq!(a, b, "{}: parity pack diverges at effect #{i}", kind.name());
        }

        assert_eq!(plain.requests, wrapped.requests, "{}", kind.name());
        assert_eq!(plain.completions, wrapped.completions, "{}", kind.name());
        assert_eq!(plain.on_cpu, wrapped.on_cpu, "{}", kind.name());
        assert_eq!(plain.on_fpga, wrapped.on_fpga, "{}", kind.name());
        assert_eq!(plain.misses, wrapped.misses, "{}", kind.name());
        assert_eq!(plain.shed, wrapped.shed, "{}", kind.name());
        assert_eq!(plain.abandoned, wrapped.abandoned, "{}", kind.name());
        assert_eq!(plain.retries, wrapped.retries, "{}", kind.name());
        assert_eq!((plain.hedges, plain.quarantines), (0, 0), "{}", kind.name());
        assert_eq!((wrapped.hedges, wrapped.quarantines), (0, 0), "{}", kind.name());
        assert_eq!(
            plain.energy_j.to_bits(),
            wrapped.energy_j.to_bits(),
            "{}: energy must not feel the parity pack",
            kind.name()
        );
        assert_eq!(plain.cost_usd.to_bits(), wrapped.cost_usd.to_bits(), "{}", kind.name());
        assert_eq!(plain.latency_ms.count(), wrapped.latency_ms.count(), "{}", kind.name());
        assert_eq!(
            plain.latency_ms.percentile(99.0).to_bits(),
            wrapped.latency_ms.percentile(99.0).to_bits(),
            "{}",
            kind.name()
        );
        // The parity pack plans nothing and the report says so.
        assert_eq!(wrapped.chaos.digest, 0, "{}", kind.name());
        assert_eq!(
            wrapped.chaos.preemptions + wrapped.chaos.failures,
            0,
            "{}",
            kind.name()
        );
    }
}

#[test]
fn severe_pack_is_non_vacuous_and_conserves_every_request() {
    let trace = chaos_trace(600.0);
    let spec = ChaosSpec::from_name("severe", 7, 0).expect("severe pack exists");
    let (r, log) = run(&SchedulerKind::spork_e(), Some(spec), &trace);

    // Non-vacuity: the pack must have planned kills, landed at least one
    // on a live worker, and forced at least one retry — otherwise the
    // suite is testing nothing.
    assert!(
        r.chaos.preemptions + r.chaos.failures > 0,
        "severe plan must contain kills"
    );
    assert!(
        r.preemptions + r.worker_failures >= 1,
        "at least one kill must strike a live worker (got {} preemptions, {} failures)",
        r.preemptions,
        r.worker_failures
    );
    assert!(r.retries >= 1, "kills must catch requests in flight");
    assert!(
        log.iter().any(|e| matches!(e, Effect::Killed { .. })),
        "applied kills must surface in the effect stream"
    );

    // The extended conservation law, exact.
    assert_eq!(
        r.requests,
        r.completions + r.shed + r.abandoned,
        "conservation violated: {} != {} completed + {} shed + {} abandoned",
        r.requests,
        r.completions,
        r.shed,
        r.abandoned
    );
    assert!(r.hedge_wins <= r.hedges, "{} wins > {} hedges", r.hedge_wins, r.hedges);
    // Applied kills can never exceed planned kills.
    assert!(r.preemptions <= r.chaos.preemptions);
    assert!(r.worker_failures <= r.chaos.failures);

    // Determinism: the same spec replays the same adversity.
    let spec = ChaosSpec::from_name("severe", 7, 0).unwrap();
    let (again, again_log) = run(&SchedulerKind::spork_e(), Some(spec), &trace);
    assert_eq!(r.chaos.digest, again.chaos.digest);
    assert_eq!(r.requests, again.requests);
    assert_eq!(r.retries, again.retries);
    assert_eq!(r.abandoned, again.abandoned);
    assert_eq!(r.energy_j.to_bits(), again.energy_j.to_bits());
    assert_eq!(log.len(), again_log.len());
}

fn chaos_app_factory(i: usize) -> AppFactory {
    Box::new(move || {
        // Pure function of the app index: the determinism contract.
        let mut rng = Rng::for_stream(42, i as u64);
        let trace = synthetic_app(
            &format!("app{i}"),
            &mut rng,
            0.6,
            300.0,
            30.0 + 5.0 * i as f64,
            0.010,
        );
        let cfg = ServeConfig::defaults("unused", 1e9);
        let sim_cfg = cfg.sim_config(8, 4);
        let policy = sched::build(&SchedulerKind::spork_e(), &sim_cfg, &trace);
        AppServe {
            source: Box::new(trace.into_source()),
            policy,
            pool_cpus: 8,
            pool_fpgas: 4,
        }
    })
}

#[test]
fn sharded_chaos_reports_are_shard_count_independent() {
    let mut cfg = ServeConfig::defaults("unused", 1e9);
    cfg.chaos = Some(ChaosSpec::from_name("severe", 42, 0).expect("severe pack exists"));
    let run = |shards: usize| {
        let apps = (0..5).map(chaos_app_factory).collect();
        run_serve_sharded(&cfg, apps, shards, Compute::Stub).unwrap()
    };
    let one = run(1);
    assert!(one.requests > 1000, "workload too small to mean anything");
    assert!(
        one.preemptions + one.worker_failures >= 1,
        "sharded severe run must apply at least one kill"
    );
    assert!(one.retries >= 1);
    assert_ne!(one.chaos.digest, 0);
    assert_eq!(one.requests, one.completions + one.shed + one.abandoned);
    for shards in [2, 4, 7] {
        let many = run(shards);
        assert_eq!(one.requests, many.requests, "{shards} shards");
        assert_eq!(one.completions, many.completions, "{shards} shards");
        assert_eq!(one.abandoned, many.abandoned, "{shards} shards");
        assert_eq!(one.retries, many.retries, "{shards} shards");
        assert_eq!(one.hedges, many.hedges, "{shards} shards");
        assert_eq!(one.hedge_wins, many.hedge_wins, "{shards} shards");
        assert_eq!(one.quarantines, many.quarantines, "{shards} shards");
        assert_eq!(one.preemptions, many.preemptions, "{shards} shards");
        assert_eq!(one.worker_failures, many.worker_failures, "{shards} shards");
        assert_eq!(one.misses, many.misses, "{shards} shards");
        assert_eq!(
            one.chaos, many.chaos,
            "plan digest/counts must be shard-count independent ({shards} shards)"
        );
        assert_eq!(
            one.energy_j.to_bits(),
            many.energy_j.to_bits(),
            "energy must merge identically at {shards} shards"
        );
        assert_eq!(one.cost_usd.to_bits(), many.cost_usd.to_bits(), "{shards} shards");
        assert_eq!(one.latency_ms.count(), many.latency_ms.count(), "{shards} shards");
        assert_eq!(
            one.latency_ms.percentile(99.0).to_bits(),
            many.latency_ms.percentile(99.0).to_bits(),
            "{shards} shards"
        );
    }
}
