//! Bench: regenerate paper fig7 at smoke scale (full scale via
//! `spork experiment fig7 --full`).
mod common;

fn main() {
    common::run_experiment_bench("fig7");
}
