//! Shared scaffolding for the custom bench harness (criterion is not in
//! the offline registry; benches are `harness = false` binaries).
//!
//! Each paper-table/figure bench runs a reduced version of its experiment
//! through `spork::exp::run`, printing the same rows the paper reports
//! plus wall time — `cargo bench` therefore regenerates every table and
//! figure at smoke scale, and `spork experiment <id> [--full]` at paper
//! scale.

use spork::exp::ExpCtx;
use std::path::PathBuf;
use std::time::Instant;

#[allow(dead_code)] // each bench target compiles this module; not all use every helper
pub fn bench_ctx() -> ExpCtx {
    ExpCtx {
        out_dir: PathBuf::from(
            std::env::var("SPORK_BENCH_OUT").unwrap_or_else(|_| "results/bench".into()),
        ),
        seeds: 1,
        scale: 0.3,
        full: false,
        // Benches time the sweep the way users run it: parallel by
        // default, overridable for serial baselines.
        jobs: std::env::var("SPORK_BENCH_JOBS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(0),
    }
}

#[allow(dead_code)]
pub fn run_experiment_bench(id: &str) {
    let ctx = bench_ctx();
    let t0 = Instant::now();
    match spork::exp::run(id, &ctx) {
        Ok(tables) => {
            eprintln!(
                "bench {id}: {} table(s) in {:.2}s",
                tables.len(),
                t0.elapsed().as_secs_f64()
            );
        }
        Err(e) => {
            eprintln!("bench {id} FAILED: {e}");
            std::process::exit(1);
        }
    }
}

/// Simple repeated-timing helper for microbenches.
#[allow(dead_code)]
pub fn time_it<F: FnMut() -> R, R>(label: &str, iters: u32, mut f: F) -> f64 {
    // Warmup.
    for _ in 0..iters.div_ceil(10).max(1) {
        std::hint::black_box(f());
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(f());
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    if per >= 1.0 {
        println!("{label:<48} {per:>10.3} s/iter");
    } else if per >= 1e-3 {
        println!("{label:<48} {:>10.3} ms/iter", per * 1e3);
    } else {
        println!("{label:<48} {:>10.3} us/iter", per * 1e6);
    }
    per
}
