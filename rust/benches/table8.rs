//! Bench: regenerate paper table8 at smoke scale (full scale via
//! `spork experiment table8 --full`).
mod common;

fn main() {
    common::run_experiment_bench("table8");
}
