//! Bench: regenerate paper fig6 at smoke scale (full scale via
//! `spork experiment fig6 --full`).
mod common;

fn main() {
    common::run_experiment_bench("fig6");
}
