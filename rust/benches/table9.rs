//! Bench: regenerate paper table9 at smoke scale (full scale via
//! `spork experiment table9 --full`).
mod common;

fn main() {
    common::run_experiment_bench("table9");
}
