//! Bench: regenerate paper fig2 at smoke scale (full scale via
//! `spork experiment fig2 --full`).
mod common;

fn main() {
    common::run_experiment_bench("fig2");
}
