//! Microbenches for the L3 hot paths: the DES engine, the dispatch
//! policies at several pool sizes, and the Alg-2 predictor (rust scalar
//! vs the XLA-offloaded artifact when `artifacts/` exists).
//!
//! Results feed EXPERIMENTS.md §Perf.

mod common;

use spork::config::{DispatchPolicy, PlatformConfig, SimConfig, WorkerKind};
use spork::sched::dispatch::Dispatcher;
use spork::sched::spork::predictor::Predictor;
use spork::config::SchedulerKind;
use spork::sched::Objective;
use spork::sim::{Request, SimState, WorkerState};
use spork::trace::synthetic_app;
use spork::util::rng::Rng;

fn bench_sweep_engine() {
    use spork::exp::{SweepCell, SweepGrid, WorkloadSpec};
    println!("-- sweep engine (SweepGrid, serial vs parallel) --");
    let build = |jobs: usize| {
        let mut grid = SweepGrid::with(2, jobs);
        for &b in &[0.55, 0.65, 0.75] {
            for kind in [SchedulerKind::spork_e(), SchedulerKind::MarkIdeal] {
                grid.push(SweepCell {
                    scheduler: kind,
                    cfg: SimConfig::paper_default(),
                    workload: WorkloadSpec {
                        burstiness: b,
                        rate: 300.0,
                        size: 0.010,
                        duration: 240.0,
                    },
                    seed_base: 71,
                    scenario: None,
                });
            }
        }
        grid
    };
    // (Byte-identical results across --jobs are pinned by
    // rust/tests/determinism.rs; this bench only measures the speedup.)
    let serial = common::time_it("sweep 6 cells x 2 seeds, --jobs 1", 2, || build(1).run());
    let auto = common::time_it("sweep 6 cells x 2 seeds, --jobs 0 (auto)", 2, || {
        build(0).run()
    });
    println!(
        "{:<48} {:>9.2}x",
        "  parallel speedup",
        serial / auto.max(1e-12)
    );
}

fn bench_sim_engine() {
    println!("-- sim engine (end-to-end DES) --");
    for &(rate, dur) in &[(500.0, 600.0), (2000.0, 600.0)] {
        let mut rng = Rng::new(1);
        let trace = synthetic_app("b", &mut rng, 0.65, dur, rate, 0.010);
        let n = trace.len();
        let cfg = SimConfig::paper_default();
        let defaults = PlatformConfig::paper_default();
        let per = common::time_it(
            &format!("sporkE sim: {n} requests"),
            3,
            || spork::sched::run_scheduler(&SchedulerKind::spork_e(), &trace, &cfg, &defaults),
        );
        println!(
            "{:<48} {:>10.2} M requests/s",
            "  throughput",
            n as f64 / per / 1e6
        );
    }
}

/// A fleet with a realistic state mix (≈60% busy, ≈30% idle, ≈10%
/// spinning up) so every indexed preference class is populated.
fn state_with_workers(n_fpga: u32, n_cpu: u32) -> SimState {
    let mut cfg = SimConfig::paper_default();
    cfg.platform.fpga.spin_up = 0.0;
    cfg.platform.cpu.spin_up = 0.0;
    let mut sim = SimState::new(cfg);
    let mut rng = Rng::new(2);
    for kind in WorkerKind::EFFICIENT_FIRST {
        let n = if kind == WorkerKind::Fpga { n_fpga } else { n_cpu };
        for _ in 0..n {
            let id = sim.alloc(kind).unwrap();
            let busy = rng.range_f64(0.0, 0.05);
            let roll = rng.below(10);
            sim.pool.with_mut(id, |w| {
                if roll < 6 {
                    w.state = WorkerState::Active;
                    w.busy_until = busy;
                    w.queued = 1;
                } else if roll < 9 {
                    w.state = WorkerState::Active;
                    w.busy_until = 0.0;
                    w.idle_since = -busy;
                } else {
                    w.state = WorkerState::SpinningUp;
                    w.ready_at = busy.max(1e-4);
                    w.busy_until = w.ready_at + busy;
                }
            });
        }
    }
    sim
}

fn bench_dispatch() {
    // The pool-size axis: O(log W) indexed dispatch should be near-flat
    // from 100 to 10k workers; an O(W) scan grows ~100x.
    println!("-- dispatch policies (pool-size scaling axis) --");
    for &pool in &[100u32, 1_000, 10_000] {
        let sim = state_with_workers(pool / 2, pool / 2);
        let req = Request {
            arrival: 0.0,
            size: 0.010,
            deadline: 0.2,
            attempt: 0,
        };
        for policy in [
            DispatchPolicy::EfficientFirst,
            DispatchPolicy::IndexPacking,
            DispatchPolicy::RoundRobin,
        ] {
            let mut d = Dispatcher::new(policy);
            common::time_it(
                &format!("{} @ pool {pool}", policy.name()),
                20_000,
                || d.find(&sim, &req, &WorkerKind::EFFICIENT_FIRST),
            );
        }
    }
}

fn bench_pool_scaling() {
    // End-to-end counterpart of bench_dispatch: full streaming replays
    // against pinned fleets (the `spork bench-sim` pool_scaling axis, at
    // reduced N so `cargo bench` stays snappy). arrivals/sec per fleet
    // size should stay within a small factor across the two decades.
    println!("-- pool-size scaling (streaming replay, pinned fleets) --");
    let n: u64 = std::env::var("SPORK_BENCH_SCALING_ARRIVALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(50_000);
    for p in spork::exp::run_pool_scaling(&[100, 1_000, 10_000], n, 1) {
        println!(
            "{:<48} {:>10.2} M arrivals/s",
            format!("  pinned fleet {:>6}: {} arrivals", p.workers, p.arrivals),
            p.arrivals_per_sec / 1e6
        );
    }
}

fn bench_fit_search() {
    // The §5.1 fitting searches, lockstep vs serial: the lockstep engine
    // batches candidates through shared stream traversals (≤ 2
    // full-trace-equivalent traversals for ordinary fits), the serial
    // engine pays one traversal per gallop/bisect probe but simulates the
    // fewest candidates. The interesting comparison is wall time next to
    // both cost metrics — `spork bench-sim --fit` writes the same
    // accounting to BENCH_fit_passes.json for CI tracking.
    use spork::sched::{fpga_dynamic, fpga_static, FitEngine};
    println!("-- §5.1 fitting searches (lockstep vs serial engines) --");
    let cfg = SimConfig::paper_default();
    let defaults = PlatformConfig::paper_default();
    let mut rng = Rng::new(9);
    let trace = synthetic_app("fit", &mut rng, 0.65, 600.0, 400.0, 0.010);
    let report = |label: &str, s: &spork::sched::FitStats| {
        println!(
            "{:<48} {} passes in {} batches, {} aborted, {:.2} stream / {:.2} \
             simulated full-trace equivalents",
            format!("  {label} cost"),
            s.pass_count(),
            s.batches.len(),
            s.aborted_passes(),
            s.full_trace_equivalents(),
            s.simulated_trace_equivalents(),
        );
    };

    let mut fitted = Vec::new();
    for engine in [FitEngine::Lockstep, FitEngine::Serial] {
        let tag = match engine {
            FitEngine::Lockstep => "lockstep",
            FitEngine::Serial => "serial",
        };
        let mut stats = None;
        common::time_it(
            &format!("fpga-static fit ({tag}): {} arrivals", trace.len()),
            3,
            || {
                let r = fpga_static::fit_source_stats_with(
                    engine,
                    &|| Box::new(trace.source()),
                    &cfg,
                    &defaults,
                    0.005,
                );
                fitted.push(("static", r.1));
                stats = Some(r.2);
            },
        );
        report(&format!("fpga-static ({tag})"), &stats.expect("timed iteration"));

        let mut stats = None;
        common::time_it(
            &format!("fpga-dynamic fit ({tag}): {} arrivals", trace.len()),
            3,
            || {
                let r = fpga_dynamic::fit_source_stats_with(
                    engine,
                    &|| Box::new(trace.source()),
                    &cfg,
                    &defaults,
                    0.005,
                );
                fitted.push(("dynamic", r.1));
                stats = Some(r.2);
            },
        );
        report(&format!("fpga-dynamic ({tag})"), &stats.expect("timed iteration"));
    }
    // The engines must agree on the fitted values (pinned properly by
    // tests/fit_parity.rs; this is a cheap sanity tripwire in the bench).
    for what in ["static", "dynamic"] {
        let vals: Vec<u32> = fitted
            .iter()
            .filter(|(w, _)| *w == what)
            .map(|&(_, v)| v)
            .collect();
        assert!(
            vals.windows(2).all(|w| w[0] == w[1]),
            "fit engines disagree on {what}: {vals:?}"
        );
    }
}

fn bench_production_parallel() {
    // The per-app fan-out inside one production cell (DESIGN.md §14):
    // apps spread over the shared executor, metrics merge in app-index
    // order. jobs=1 forces the inline serial loop; jobs=0 takes the full
    // budget. (Bit-identical cells across jobs are pinned by
    // rust/tests/production_parallel.rs; this measures the speedup.
    // `spork bench-sim --par-apps` is the CI-tracked counterpart.)
    use spork::config::SizeBucket;
    use spork::trace::production::{self, Dataset, ProductionParams};
    println!("-- per-app parallel production cell (--par-apps axis) --");
    let params = ProductionParams {
        dataset: Dataset::AzureFunctions,
        bucket: SizeBucket::Short,
        duration: 600.0,
        scale: 0.05,
        max_apps: Some(8),
    };
    let apps = production::generate(&params, &mut Rng::new(21));
    let arrivals: usize = apps.iter().map(|a| a.len()).sum();
    let cfg = SimConfig::paper_default();
    let kind = SchedulerKind::spork_e();
    let serial = common::time_it(
        &format!("production cell {} apps / {arrivals} arrivals, jobs 1", apps.len()),
        2,
        || spork::exp::common::run_production_jobs(&kind, &cfg, &apps, 1),
    );
    let auto = common::time_it(
        &format!("production cell {} apps / {arrivals} arrivals, jobs 0", apps.len()),
        2,
        || spork::exp::common::run_production_jobs(&kind, &cfg, &apps, 0),
    );
    println!(
        "{:<48} {:>9.2}x",
        "  per-app parallel speedup",
        serial / auto.max(1e-12)
    );
}

fn bench_predictor() {
    println!("-- Alg 2 predictor --");
    let mut p = Predictor::new(PlatformConfig::paper_default(), 10.0, Objective::energy());
    let mut rng = Rng::new(3);
    for _ in 0..2000 {
        let key = rng.below(32) as u32;
        p.observe(key, rng.below(48) as u32);
    }
    let mut i = 0u32;
    common::time_it("rust predictor (cached)", 100_000, || {
        i = (i + 1) % 32;
        p.predict(i, 8)
    });
    // Force uncached predictions by invalidating each round.
    let mut j = 0u32;
    common::time_it("rust predictor (uncached)", 5_000, || {
        j = (j + 1) % 32;
        p.observe(j, (j * 7) % 48);
        p.predict(j, 8)
    });

    // XLA-offloaded expectation (if artifacts are present).
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let rt = spork::runtime::Runtime::load("artifacts").expect("runtime");
        let exe = rt.compile("predictor").expect("compile predictor");
        let probs = vec![1.0 / 64.0f32; 64];
        let bins: Vec<f32> = (0..64).map(|i| i as f32).collect();
        let cands = bins.clone();
        let knobs = vec![
            10.0,
            50.0,
            20.0,
            150.0,
            2.0,
            0.982 / 3600.0,
            0.668 / 3600.0,
            1.0,
            0.0,
        ];
        common::time_it("xla predictor (64x64 expectation)", 2_000, || {
            exe.run_f32(&[&probs, &bins, &cands, &knobs]).unwrap()
        });
    } else {
        println!("xla predictor: skipped (run `make artifacts`)");
    }
}

fn bench_streaming_replay() {
    // The perf-trajectory headline: a long synthetic trace through the
    // streaming path (constant memory in trace length). Defaults to 200k
    // arrivals to keep `cargo bench` snappy; set SPORK_BENCH_ARRIVALS
    // (e.g. 1000000) for the full datacenter-scale replay. Runs FIRST in
    // main(): VmHWM is a process-lifetime high-water mark, so the RSS
    // figure is only meaningful before the materialized benches run.
    // (`spork bench-sim` is the canonical standalone-process number and
    // writes BENCH_sim_throughput.json for CI artifact tracking.)
    println!("-- streaming replay (spork bench-sim harness) --");
    let n: u64 = std::env::var("SPORK_BENCH_ARRIVALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(200_000);
    let r = spork::exp::run_bench_sim(&SchedulerKind::spork_e(), n, 2000.0, 1);
    println!(
        "{:<48} {:>10.2} M arrivals/s",
        format!("  sporkE streaming: {} arrivals", r.arrivals),
        r.arrivals_per_sec / 1e6
    );
    println!(
        "{:<48} {:>9} kB",
        "  peak RSS (VmHWM proxy)", r.peak_rss_kb
    );
}

fn main() {
    bench_streaming_replay();
    bench_pool_scaling();
    bench_sweep_engine();
    bench_sim_engine();
    bench_dispatch();
    bench_fit_search();
    bench_production_parallel();
    bench_predictor();
}
