//! Bench: regenerate paper fig3 at smoke scale (full scale via
//! `spork experiment fig3 --full`).
mod common;

fn main() {
    common::run_experiment_bench("fig3");
}
