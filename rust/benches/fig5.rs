//! Bench: regenerate paper fig5 at smoke scale (full scale via
//! `spork experiment fig5 --full`).
mod common;

fn main() {
    common::run_experiment_bench("fig5");
}
