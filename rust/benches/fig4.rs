//! Bench: regenerate paper fig4 at smoke scale (full scale via
//! `spork experiment fig4 --full`).
mod common;

fn main() {
    common::run_experiment_bench("fig4");
}
