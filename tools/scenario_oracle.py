#!/usr/bin/env python3
"""Logic oracle for the scenario fault-plan generator.

Re-implements, from scratch and in Python, the exact deterministic
pipeline `rust/src/scenario/plan.rs` uses to materialize a FaultPlan:

    SplitMix64 -> xoshiro256++ -> Rng::for_stream -> per-(kind, channel)
    streams -> OU price walk + hazard Bernoulli strikes + exponential-gap
    failures -> stable time sort -> order-sensitive digest

and checks that the two implementations agree bit-for-bit. Integer and
RNG state arithmetic is exact by construction (64-bit wrapping); float
arithmetic matches because both sides do the same IEEE-754 double
operations in the same order (transcendentals resolve to the platform
libm in both runtimes).

Usage:
    tools/scenario_oracle.py pinned
        Re-derive the constants the Rust unit tests pin (plan counts for
        the packs at fixed seeds) and print them; fails if the severe
        pack is vacuous over the CI smoke window.

    tools/scenario_oracle.py verify BENCH_scenario.json
        Recompute the fault plan declared by a `spork bench-sim
        --scenario` report and compare planned counts AND the full plan
        digest against what the Rust generator produced. Any diverging
        bit fails the run.

    tools/scenario_oracle.py verify-serve BENCH_serve_chaos.json
        Same, for the serve-path chaos axis (`spork bench-serve --chaos`):
        rebuild every per-app plan (app i is seeded `seed + i`), fold the
        per-app digests in app-index order with the digest's own mixing
        step, and compare the combined digest and summed planned counts
        against the report. Also audits the run itself: the extended
        conservation law `requests == completions + shed + abandoned`,
        `hedge_wins <= hedges`, and (for an adverse pack) that faults and
        retries were actually exercised.
"""

import json
import math
import struct
import sys

MASK = (1 << 64) - 1
GOLDEN = 0x9E3779B97F4A7C15
SCENARIO_SALT = 0x5CE7A210FA570B1E


# ---------------------------------------------------------------- RNG --

class SplitMix64:
    def __init__(self, seed):
        self.state = seed & MASK

    def next_u64(self):
        self.state = (self.state + GOLDEN) & MASK
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        return (z ^ (z >> 31)) & MASK


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class Xoshiro256pp:
    def __init__(self, seed):
        sm = SplitMix64(seed)
        self.s = [sm.next_u64() for _ in range(4)]

    def next_u64(self):
        s = self.s
        result = (_rotl((s[0] + s[3]) & MASK, 23) + s[0]) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return result


class Rng:
    """Mirror of `spork::util::rng::Rng` (only the draws the plan uses)."""

    def __init__(self, inner):
        self.inner = inner

    @staticmethod
    def for_stream(seed, stream):
        sm = SplitMix64(seed)
        base = sm.next_u64()
        sm = SplitMix64(base ^ ((stream * GOLDEN) & MASK))
        return Rng(Xoshiro256pp(sm.next_u64()))

    def f64(self):
        return (self.inner.next_u64() >> 11) * (1.0 / (1 << 53))

    def chance(self, p):
        return self.f64() < p

    def exp(self, rate):
        return -math.log(1.0 - self.f64()) / rate

    def normal(self, mu, sigma):
        u1 = 1.0 - self.f64()
        u2 = self.f64()
        mag = math.sqrt(-2.0 * math.log(u1))
        return mu + sigma * mag * math.cos(2.0 * math.pi * u2)


# ------------------------------------------------------- scenario packs --

class Ou:
    def __init__(self, mu, theta, sigma, daily_amp, period, floor, init):
        self.mu = mu
        self.theta = theta
        self.sigma = sigma
        self.daily_amp = daily_amp
        self.period = period
        self.floor = floor
        self.init = init

    def mean_at(self, t):
        return self.mu * (1.0 + self.daily_amp * math.sin(2.0 * math.pi * t / self.period))

    def step(self, x, t, dt, z):
        nxt = x + self.theta * (self.mean_at(t) - x) * dt + self.sigma * math.sqrt(dt) * z
        return max(nxt, self.floor)


class KindScenario:
    def __init__(self, spot=False, price=None, preempt_rate=0.0,
                 hazard_gamma=0.0, mttf=math.inf):
        self.spot = spot
        self.price = price or Ou(1.0, 0.0, 0.0, 0.0, 86400.0, 1.0, 1.0)
        self.preempt_rate = preempt_rate
        self.hazard_gamma = hazard_gamma
        self.mttf = mttf


# kinds are indexed by WorkerKind::index(): 0 = Cpu, 1 = Fpga.
PACKS = {
    "fault-free": ([KindScenario(), KindScenario()], 1.0, 0),
    "mild": (
        [
            KindScenario(),
            KindScenario(
                spot=True,
                price=Ou(0.35, 1.0 / 600.0, 0.006, 0.25, 86400.0, 0.05, 0.35),
                preempt_rate=1.0 / 600.0,
                hazard_gamma=2.0,
                mttf=86400.0,
            ),
        ],
        1.0,
        0,
    ),
    "severe": (
        [
            KindScenario(mttf=7200.0),
            KindScenario(
                spot=True,
                price=Ou(0.30, 1.0 / 300.0, 0.012, 0.35, 86400.0, 0.05, 0.30),
                preempt_rate=0.1,
                hazard_gamma=3.0,
                mttf=3600.0,
            ),
        ],
        1.0,
        0,
    ),
}


# ------------------------------------------------------------ the plan --

TAG_TICK, TAG_PREEMPTION, TAG_FAILURE = 1, 2, 3


def build_plan(pack_name, seed_base, seed, duration):
    """Returns [(time, tag, kind_index, payload)] sorted like plan.rs."""
    kinds, price_dt, seed_salt = PACKS[pack_name]
    faults = []
    if not math.isfinite(duration) or duration <= 0.0:
        return faults
    root = (seed_base ^ SCENARIO_SALT ^ seed_salt) & MASK

    def stream(k, ch):
        return ((seed * 8) + (k * 3) + ch) & MASK

    for k, ks in enumerate(kinds):
        if ks.spot:
            price_rng = Rng.for_stream(root, stream(k, 0))
            strike_rng = Rng.for_stream(root, stream(k, 1))
            dt = price_dt
            x = max(ks.price.init, ks.price.floor)
            i = 0
            while True:
                t = float(i) * dt
                if t >= duration:
                    break
                if i > 0:
                    x = ks.price.step(x, t, dt, price_rng.normal(0.0, 1.0))
                    faults.append((t, TAG_TICK, k, x))
                if ks.preempt_rate > 0.0:
                    hazard = ks.preempt_rate * math.pow(ks.price.mu / x, ks.hazard_gamma)
                    p = min(hazard * dt, 1.0)
                    if strike_rng.chance(p):
                        offset = strike_rng.f64()
                        victim_draw = strike_rng.f64()
                        faults.append((t + offset * dt, TAG_PREEMPTION, k, victim_draw))
                i += 1
        if math.isfinite(ks.mttf) and ks.mttf > 0.0:
            fail_rng = Rng.for_stream(root, stream(k, 2))
            t = fail_rng.exp(1.0 / ks.mttf)
            while t < duration:
                victim_draw = fail_rng.f64()
                faults.append((t, TAG_FAILURE, k, victim_draw))
                t += fail_rng.exp(1.0 / ks.mttf)
    faults.sort(key=lambda f: f[0])  # stable, same as Rust's sort_by total_cmp
    return faults


def f64_bits(x):
    return struct.unpack("<Q", struct.pack("<d", x))[0]


def digest(faults):
    h = 0
    for time, tag, kind, payload in faults:
        for v in (f64_bits(time), tag * 4 + kind, f64_bits(payload)):
            h = ((_rotl(h, 7) ^ v) * GOLDEN) & MASK
    return h


def counts(faults):
    ticks = sum(1 for f in faults if f[1] == TAG_TICK)
    preempts = sum(1 for f in faults if f[1] == TAG_PREEMPTION)
    fails = sum(1 for f in faults if f[1] == TAG_FAILURE)
    return ticks, preempts, fails


# ---------------------------------------------------------------- modes --

def cmd_pinned():
    """Mirror the constants rust unit tests pin; fail on vacuity."""
    ok = True

    plan = build_plan("fault-free", 1, 0, 3600.0)
    print(f"fault-free (1,0,3600s): {len(plan)} faults, digest {digest(plan):#018x}")
    if plan or digest(plan) != 0:
        print("FAIL: fault-free pack must plan nothing (digest 0)")
        ok = False

    plan = build_plan("severe", 1, 0, 50.0)
    ticks, preempts, fails = counts(plan)
    print(f"severe (1,0,50s): ticks={ticks} preemptions={preempts} failures={fails} "
          f"digest={digest(plan):#018x}")
    if ticks != 49:
        print("FAIL: severe/50s must tick once per dt after t=0 (expected 49)")
        ok = False
    if preempts == 0:
        print("FAIL: severe/50s planned no strikes (vacuous smoke window)")
        ok = False

    a = build_plan("severe", 1, 0, 600.0)
    b = build_plan("severe", 1, 0, 600.0)
    if digest(a) != digest(b):
        print("FAIL: same cell must produce an identical plan")
        ok = False
    if digest(a) == digest(build_plan("severe", 1, 1, 600.0)):
        print("FAIL: the seed must move the plan")
        ok = False

    mild = counts(build_plan("mild", 1, 0, 3600.0))
    severe = counts(build_plan("severe", 1, 0, 3600.0))
    print(f"mild (1,0,3600s): ticks={mild[0]} preemptions={mild[1]} failures={mild[2]}")
    print(f"severe (1,0,3600s): ticks={severe[0]} preemptions={severe[1]} failures={severe[2]}")
    if severe[1] <= mild[1]:
        print("FAIL: severe must strike more than mild")
        ok = False

    print("pinned-constant check:", "OK" if ok else "FAILED")
    return 0 if ok else 1


def cmd_verify(path):
    with open(path) as f:
        report = json.load(f)
    pack = report["scenario"]
    if pack not in PACKS:
        print(f"FAIL: unknown scenario pack {pack!r} in {path}")
        return 1
    plan = build_plan(pack, report["seed_base"], report["seed"],
                      float(report["sim_seconds"]))
    ticks, preempts, fails = counts(plan)
    d = digest(plan)
    want = (report["plan_price_ticks"], report["plan_preemptions"],
            report["plan_failures"], int(report["plan_digest"], 16))
    got = (ticks, preempts, fails, d)
    print(f"pack={pack} seed_base={report['seed_base']} seed={report['seed']} "
          f"duration={report['sim_seconds']}s")
    print(f"  rust:   ticks={want[0]} preemptions={want[1]} failures={want[2]} "
          f"digest={want[3]:#018x}")
    print(f"  python: ticks={got[0]} preemptions={got[1]} failures={got[2]} "
          f"digest={got[3]:#018x}")
    if got != want:
        print("FAIL: the Python oracle and the Rust generator disagree")
        return 1
    if pack != "fault-free":
        applied = report["preemptions"] + report["worker_failures"]
        if applied == 0:
            print("FAIL: adverse pack applied zero faults at runtime (vacuous)")
            return 1
        if report["arrivals"] != report["completions"] + report["abandoned"]:
            print("FAIL: arrival conservation violated in the report")
            return 1
    print("scenario oracle: OK (plan counts and digest match bit-for-bit)")
    return 0


def combine_digest(h, app_digest):
    """Mirror of `spork::serve::chaos::combine_digest`."""
    return ((_rotl(h, 7) ^ app_digest) * GOLDEN) & MASK


def cmd_verify_serve(path):
    with open(path) as f:
        report = json.load(f)
    pack = report["pack"]
    if pack not in PACKS:
        print(f"FAIL: unknown chaos pack {pack!r} in {path}")
        return 1
    seed_base = report["seed_base"]
    seed = report["seed"]
    apps = int(report["apps"])
    duration = float(report["sim_seconds"])
    combined = 0
    ticks = preempts = fails = 0
    for i in range(apps):
        plan = build_plan(pack, seed_base, (seed + i) & MASK, duration)
        t, p, fl = counts(plan)
        ticks += t
        preempts += p
        fails += fl
        combined = combine_digest(combined, digest(plan))
    want = (report["planned_price_ticks"], report["planned_preemptions"],
            report["planned_failures"], int(report["plan_digest"], 16))
    got = (ticks, preempts, fails, combined)
    print(f"pack={pack} seed_base={seed_base} seed={seed} apps={apps} "
          f"duration={duration}s")
    print(f"  rust:   ticks={want[0]} preemptions={want[1]} failures={want[2]} "
          f"digest={want[3]:#018x}")
    print(f"  python: ticks={got[0]} preemptions={got[1]} failures={got[2]} "
          f"digest={got[3]:#018x}")
    if got != want:
        print("FAIL: the Python oracle and the Rust chaos replay disagree")
        return 1

    accounted = report["completions"] + report["shed"] + report["abandoned"]
    if report["requests"] != accounted:
        print(f"FAIL: conservation violated: {report['requests']} requests != "
              f"{report['completions']} completions + {report['shed']} shed + "
              f"{report['abandoned']} abandoned")
        return 1
    if report["hedge_wins"] > report["hedges"]:
        print(f"FAIL: hedge accounting violated: {report['hedge_wins']} wins > "
              f"{report['hedges']} hedges")
        return 1
    if pack != "fault-free":
        if preempts + fails == 0:
            print("FAIL: adverse pack planned zero kills (vacuous window)")
            return 1
        applied = report["preemptions"] + report["worker_failures"]
        if applied == 0:
            print("FAIL: adverse pack applied zero faults at runtime (vacuous)")
            return 1
        if report["retries"] == 0:
            print("FAIL: faults struck but zero retries were exercised (vacuous)")
            return 1
        if report["preemptions"] > preempts or report["worker_failures"] > fails:
            print("FAIL: more faults applied than the plan contains")
            return 1
    print("serve-chaos oracle: OK (combined digest, planned counts, and "
          "conservation all check out)")
    return 0


def main(argv):
    if len(argv) >= 2 and argv[1] == "pinned":
        return cmd_pinned()
    if len(argv) >= 3 and argv[1] == "verify":
        return cmd_verify(argv[2])
    if len(argv) >= 3 and argv[1] == "verify-serve":
        return cmd_verify_serve(argv[2])
    print(__doc__)
    return 2


if __name__ == "__main__":
    sys.exit(main(sys.argv))
